"""Fault-tolerant checkpointing, from scratch.

Features required at 1000+-node scale (single-host implementation, multi-host
notes in DESIGN.md):

* atomic writes — serialize to ``<dir>/tmp.<step>`` then ``os.rename`` so a
  preempted writer never corrupts the latest checkpoint;
* async saves — device_get on the main thread (cheap), compression + disk IO on a
  background thread so the step loop is not blocked;
* integrity — sha256 of the payload stored in ``meta.json`` and verified on load;
* keep-N garbage collection;
* **elastic restore** — tensors are stored by tree path with their *logical* axes;
  `restore` lays them out onto any mesh via the current sharding rules, so a job
  checkpointed on 16x16 resumes on 2x16x16 (or 1 CPU device) unchanged.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.utils.pytrees import flatten_with_paths


def _tree_to_arrays(tree):
    return {path: np.asarray(jax.device_get(leaf))
            for path, leaf in flatten_with_paths(tree)}


def _rebuild(template, arrays: dict, shardings=None):
    flat = flatten_with_paths(template)
    sflat = flatten_with_paths(shardings) if shardings is not None else None
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        if path not in arrays:
            raise KeyError(f"checkpoint missing tensor {path!r}")
        arr = arrays[path]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if sflat is not None:
            arr = jax.device_put(arr, sflat[i][1])
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, extra: Optional[dict] = None,
             block: bool = False):
        arrays = _tree_to_arrays(state)      # device_get happens synchronously
        self.wait()                          # one in-flight save at a time

        def write():
            buf = io.BytesIO()
            np.savez(buf, **{k.replace("/", "\x1f"): v
                             for k, v in arrays.items()})
            payload = buf.getvalue()
            digest = hashlib.sha256(payload).hexdigest()
            tmp = os.path.join(self.dir, f".tmp.{step}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "state.npz"), "wb") as f:
                f.write(payload)
            meta = {"step": step, "sha256": digest, "time": time.time(),
                    "extra": extra or {}}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                import shutil
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomic publish
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None, verify: bool = True):
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(path, "state.npz"), "rb") as f:
            payload = f.read()
        if verify:
            digest = hashlib.sha256(payload).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint {path} failed integrity check")
        npz = np.load(io.BytesIO(payload))
        arrays = {k.replace("\x1f", "/"): npz[k] for k in npz.files}
        return _rebuild(template, arrays, shardings), meta

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, template, shardings)
