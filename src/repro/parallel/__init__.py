from repro.parallel.sharding import (RULES, make_shard_fn, batch_shardings,
                                     cache_shardings, activation_pspec)
from repro.parallel.collectives import compressed_psum_pod, hierarchical_pmean
