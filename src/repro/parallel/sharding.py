"""Sharding rule tables + activation-sharding hooks.

Logical axes used across the framework:

    params:      embed, vocab, mlp, heads, expert, (None)
    activations: batch, seq, embed, mlp, heads, expert, kv_heads

Rule presets (values: None | mesh-axis | tuple of mesh axes):

* ``train_fsdp_tp``  — baseline: weights FSDP over (pod,data) on the embed dim and
  tensor-parallel over `model` on mlp/heads/vocab; experts expert-parallel over
  `model`; batch data-parallel. ZeRO-style optimizer sharding comes free (opt
  state shardings mirror param shardings under pjit).
* ``train_fsdp_tp_sp`` — + sequence parallelism: the residual stream's `seq` dim is
  sharded over `model` between blocks (activation memory / norm compute / collective
  trade-off — a §Perf hillclimb lever).
* ``serve_2d``      — serving: 2D weight sharding (embed over data, mlp/heads/vocab
  over model) so ≥60B bf16 params fit 256 x 16 GB; KV cache batch over data and
  kv_heads over model where divisible.

All pspec construction is dim-size aware (non-dividing axes are dropped), so the
same rules work for every architecture (1-KV-head gemma3 included).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.param import logical_to_pspec

RULES = {
    "train_fsdp_tp": {
        "embed": ("pod", "data"),
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "seq": None,
        "kv_heads": "model",
    },
    "train_fsdp_tp_sp": {
        "embed": ("pod", "data"),
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "seq": "model",
        "kv_heads": "model",
    },
    # paper-faithful naive distribution: pure DP (weights replicated) — the
    # single-GPU paper setup scaled the obvious way; kept as the §Perf baseline.
    "train_dp": {
        "embed": None, "vocab": None, "mlp": None, "heads": None,
        "expert": None, "batch": ("pod", "data"), "seq": None, "kv_heads": None,
    },
    "serve_2d": {
        "embed": "data",
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        # cache/activation seq sharded over model: a 1.4 TB decode_32k KV cache
        # becomes ~5 GB/chip, and the one-position cache write stays local
        # (spike-verified: no gather, only partial-softmax all-reduces).
        "seq": "model",
        "kv_heads": "model",
    },
    # long-context serving: shard the cache/sequence dim over `model`
    "serve_longctx": {
        "embed": "data",
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "expert": "model",
        "batch": ("pod", "data"),
        "seq": "model",
        "kv_heads": None,
    },
}


def activation_pspec(shape, logical_names, mesh: Mesh, rules: dict) -> P:
    return logical_to_pspec(logical_names, rules, mesh, shape)


def make_shard_fn(mesh: Mesh, rules: dict):
    """Activation-sharding hook for models.Ctx: f(x, logical_names) -> x."""
    def shard(x, names):
        if mesh is None:
            return x
        spec = logical_to_pspec(names, rules, mesh, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return shard


def batch_shardings(batch_specs, mesh: Mesh, rules: dict):
    """NamedShardings for an input-batch tree of ShapeDtypeStructs.

    tokens/labels: (B, S) -> (batch, seq); embeds: (B, S, D); positions etc.
    """
    def one(path_leaf):
        s = path_leaf
        if len(s.shape) == 1:
            names = ("batch",)
        elif len(s.shape) == 2:
            names = ("batch", "seq")
        elif len(s.shape) == 3:
            names = ("batch", "seq", "embed")
        else:
            names = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, logical_to_pspec(names, rules, mesh, s.shape))
    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, mesh: Mesh, rules: dict):
    """NamedShardings for a decode-cache tree (shape-aware, per entry kind)."""
    def one_entry(name, s):
        shp = s.shape
        if name in ("k", "v", "ck", "cv"):
            names = ("batch", "seq", "kv_heads", None)
        elif name == "h":                     # mamba (B, DI, N)
            names = ("batch", "mlp", None)
        elif name == "conv":                  # (B, K-1, DI)
            names = ("batch", None, "mlp")
        elif name == "C":                     # mlstm (B, H, hd, hd)
            # shard the matrix memory's value dim over `model` ("mlp" rule):
            # heads (often < mesh axis) drop out, so without this every chip
            # replicates the full state update (§Perf cell-B iteration 1:
            # 565 -> ~40 MB/chip/token on xlstm long_500k).
            names = ("batch", "heads", None, "mlp")
        elif name == "n":
            names = ("batch", "heads", "mlp") if len(shp) == 3 \
                else ("batch", None)
        elif name == "c":                     # slstm (B, D)
            names = ("batch", "embed")
        else:
            names = ("batch",) + (None,) * (len(shp) - 1)
        return NamedSharding(mesh, logical_to_pspec(names, rules, mesh, shp))

    return {lname: {k: one_entry(k, v) for k, v in blk.items()}
            for lname, blk in cache_specs.items()}
