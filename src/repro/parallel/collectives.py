"""Distributed-optimization tricks: compressed + hierarchical gradient reduction.

Cross-pod links are the scarcest bandwidth at 1000+-node scale.  We provide an
int8 error-feedback compressed all-reduce for the `pod` axis, implemented with
shard_map so the quantize -> psum -> dequantize pipeline is explicit and the
residual (error feedback) stays local — standard 1-bit/8-bit Adam-style technique,
convergence-safe because the quantization error is re-injected next step.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh


def _quantize_int8(x, scale_eps=1e-12):
    amax = jnp.max(jnp.abs(x)) + scale_eps
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_pod(grads, residuals, mesh: Mesh, axis: str = "pod"):
    """Error-feedback int8 all-reduce of `grads` over `axis`.

    grads/residuals: pytrees of identically-sharded arrays. Returns
    (reduced_grads, new_residuals).  Leaves smaller than 1 KiB skip compression
    (scales/latency dominate).
    """
    if axis not in mesh.axis_names:
        return grads, residuals

    def leaf_reduce(g, r):
        x = g + r
        if x.size < 256:
            return jax.lax.pmean(x, axis), jnp.zeros_like(r)
        q, scale = _quantize_int8(x)
        deq = q.astype(x.dtype) * scale
        new_r = x - deq                      # error feedback
        red = jax.lax.pmean(deq, axis)
        return red, new_r

    def mapped(g, r):
        return jax.tree.map(leaf_reduce, g, r,
                            is_leaf=lambda v: isinstance(v, jax.Array))

    # shard_map with full replication over `axis`, identity over others: we rely on
    # callers passing per-pod replicas (standard DP gradients).
    return mapped(grads, residuals)


def hierarchical_pmean(x, mesh: Mesh):
    """Reduce over data-parallel axes in bandwidth order: data (intra-pod ICI)
    first, then pod (DCI). XLA emits two staged all-reduces instead of one flat
    global ring — the canonical hierarchy for multi-pod topologies."""
    if "data" in mesh.axis_names:
        x = jax.lax.pmean(x, "data")
    if "pod" in mesh.axis_names:
        x = jax.lax.pmean(x, "pod")
    return x
