"""Mesh factories for the production topologies.

Functions, not module-level constants — importing this module never touches jax
device state (required for the dry-run's forced-512-device bootstrap).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips single pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import")
    # more devices than needed (e.g. 512 forced, single-pod 256 mesh): use a prefix
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_mesh(data: int, model: int, pods: int = 1) -> Mesh:
    """Elastic mesh: any (pods, data, model) factorization of the device count."""
    if pods > 1:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh() -> Mesh:
    """Single-process mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    dev = np.asarray(jax.devices()).reshape(1, n)
    return Mesh(dev, ("data", "model"))
