"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed on the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh for every assigned cell; per-cell JSON records memory analysis,
HLO cost analysis, collective bytes, and roofline terms (EXPERIMENTS.md reads
these).

Usage:
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all [--jobs 4]      # driver: subprocess/cell
"""
# The forced device count MUST precede any other import that touches jax.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_collectives
from repro.analysis.roofline import Roofline, model_flops, active_params
from repro.configs import ARCHS, arch_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import lm
from repro.models.config import SHAPES
from repro.nn.param import abstract_params, param_shardings
from repro.parallel.sharding import RULES, batch_shardings, cache_shardings
from repro.serve.engine import make_prefill_step, make_decode_step
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, jit_train_step
from repro.utils import tree_param_count

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
TRAIN_RULES = os.environ.get("REPRO_TRAIN_RULES", "train_fsdp_tp")
SERVE_RULES = os.environ.get("REPRO_SERVE_RULES", "serve_2d")
EMT_RNG = os.environ.get("REPRO_EMT_RNG", "hash")
EMT_MODE = os.environ.get("REPRO_EMT_MODE", "analog")


def _measure(lowered, label: str) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll = analyze_collectives(text)
    return {
        "label": label,
        "compile_s": round(compile_s, 2),
        "flops_per_chip": float(cost.get("flops", 0.0)),
        "bytes_per_chip": float(cost.get("bytes accessed", 0.0)),
        "peak_bytes_per_chip": int(mem.peak_memory_in_bytes),
        "arg_bytes_per_chip": int(mem.argument_size_in_bytes),
        "temp_bytes_per_chip": int(mem.temp_size_in_bytes),
        "output_bytes_per_chip": int(mem.output_size_in_bytes),
        **coll,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    t_start = time.time()

    if shape.kind == "train":
        cfg = get_config(arch, emt_mode=EMT_MODE, rng=EMT_RNG)
        n_params_probe = tree_param_count(abstract_params(lm.specs(cfg)))
        opt_name = "adafactor" if n_params_probe > 30e9 else "adamw"
        tcfg = TrainConfig(opt=OptimizerConfig(name=opt_name))
        bspecs = input_specs(cfg, shape)
        with mesh:
            jitted, state_sh, astate, _ = jit_train_step(
                cfg, tcfg, mesh, bspecs, rules_name=TRAIN_RULES)
            lowered = jitted.lower(astate, bspecs)
            res = _measure(lowered, "train_step")
        n_params = n_params_probe
        extra = {"optimizer": opt_name, "rules": TRAIN_RULES}
    else:
        cfg = get_config(arch, emt_mode=EMT_MODE, rng=EMT_RNG,
                         energy_accounting="off",
                         store_int8=os.environ.get("REPRO_SERVE_INT8") == "1")
        rules = RULES[SERVE_RULES]
        pspecs = lm.specs(cfg)
        aparams = abstract_params(pspecs)
        n_params = tree_param_count(aparams)
        psh = param_shardings(pspecs, mesh, rules)
        ins = input_specs(cfg, shape)
        with mesh:
            if shape.kind == "prefill":
                csp = lm.init_cache_specs(cfg, shape.global_batch, shape.seq_len)
                csh = cache_shardings(csp, mesh, rules)
                bsh = batch_shardings(ins, mesh, rules)
                step = make_prefill_step(cfg, mesh, rules)
                jitted = jax.jit(step, in_shardings=(psh, bsh, csh, None),
                                 out_shardings=(csh, None, None))
                lowered = jitted.lower(
                    aparams, ins, csp, jax.ShapeDtypeStruct((), jnp.uint32))
                res = _measure(lowered, "prefill_step")
            else:
                csp = ins["cache"]
                csh = cache_shardings(csp, mesh, rules)
                tsh = NamedSharding(mesh, P(
                    ("pod", "data") if multi_pod else "data")) \
                    if shape.global_batch % (chips // 16) == 0 and \
                    shape.global_batch > 1 else NamedSharding(mesh, P(None))
                step = make_decode_step(cfg, mesh, rules)
                jitted = jax.jit(step,
                                 in_shardings=(psh, csh, tsh, None, None),
                                 out_shardings=(None, csh, None),
                                 donate_argnums=(1,))
                lowered = jitted.lower(
                    aparams, csp, ins["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.uint32))
                res = _measure(lowered, "serve_step")
        extra = {"rules": SERVE_RULES}

    n_active = active_params(cfg, n_params)
    mf = model_flops(cfg, shape, n_params, n_active)
    roof = Roofline(
        flops_per_chip=res["flops_per_chip"],
        bytes_per_chip=res["bytes_per_chip"],
        coll_bytes_per_chip=res["collective_bytes_per_chip"],
        chips=chips,
        model_flops_global=mf,
    ).terms()

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "n_params": int(n_params), "n_active": int(n_active),
        "emt_mode": EMT_MODE, "emt_rng": EMT_RNG,
        "wall_s": round(time.time() - t_start, 1),
        **extra, **res, "roofline": roof,
    }


def cell_filename(arch, shape, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}__{shape}__{mesh}.json"


def _cell_cost(arch, shape_name):
    """Rough compile-cost estimate — the driver runs cheap cells first so a
    time-bounded run completes the maximum number of cells."""
    cfg = get_config(arch, emt_mode="ideal")
    kind_w = {"train": 4.0, "prefill": 2.5, "decode": 1.0}[SHAPES[shape_name].kind]
    return kind_w * cfg.num_layers * (cfg.d_model ** 0.5)


def all_cells(include_multipod=True):
    cells = []
    for arch in ARCHS:
        for shape in arch_shapes(arch):
            cells.append((arch, shape, False))
            if include_multipod:
                cells.append((arch, shape, True))
    cells.sort(key=lambda c: _cell_cost(c[0], c[1]))
    return cells


def run_driver(jobs: int, force: bool, timeout: int, only_missing=True):
    os.makedirs(OUT_DIR, exist_ok=True)
    cells = all_cells()
    pending = []
    for arch, shape, mp in cells:
        path = os.path.join(OUT_DIR, cell_filename(arch, shape, mp))
        if not force and only_missing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    continue
        pending.append((arch, shape, mp))
    print(f"[driver] {len(pending)}/{len(cells)} cells to run, jobs={jobs}")

    procs = {}
    idx = 0
    failures = []
    while idx < len(pending) or procs:
        while idx < len(pending) and len(procs) < jobs:
            arch, shape, mp = pending[idx]
            path = os.path.join(OUT_DIR, cell_filename(arch, shape, mp))
            if os.path.exists(path):            # done meanwhile (re-entrancy)
                try:
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            idx += 1
                            continue
                except Exception:
                    pass
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp:
                cmd.append("--multi-pod")
            p = subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                                 stderr=subprocess.PIPE, text=True,
                                 env=dict(os.environ))
            procs[p] = (arch, shape, mp, time.time())
            idx += 1
        time.sleep(3)
        for p in list(procs):
            arch, shape, mp, t0 = procs[p]
            if p.poll() is not None:
                del procs[p]
                tag = f"{arch}/{shape}/{'mp' if mp else 'sp'}"
                if p.returncode == 0:
                    print(f"[driver] OK   {tag}  ({time.time()-t0:.0f}s)")
                else:
                    err = p.stderr.read()[-2000:]
                    failures.append((tag, err))
                    print(f"[driver] FAIL {tag}\n{err}")
            elif time.time() - t0 > timeout:
                p.kill()
                failures.append((f"{arch}/{shape}", "timeout"))
                print(f"[driver] TIMEOUT {arch}/{shape}")
                del procs[p]
    print(f"[driver] done, {len(failures)} failures")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    if args.all:
        failures = run_driver(args.jobs, args.force, args.timeout)
        sys.exit(1 if failures else 0)

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR,
                        cell_filename(args.arch, args.shape, args.multi_pod))
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "status")}))
        raise
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    roof = rec["roofline"]
    print(json.dumps({
        "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        "compile_s": rec["compile_s"],
        "peak_gb": round(rec["peak_bytes_per_chip"] / 2**30, 2),
        "dominant": roof["dominant"],
        "terms_ms": {k: round(v * 1e3, 3) for k, v in roof.items()
                     if k.endswith("_s") and not k.startswith("step")},
        "useful": round(roof["useful_flops_ratio"], 3),
        "while": rec["num_while"],
    }))


if __name__ == "__main__":
    main()
