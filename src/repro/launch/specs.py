"""ShapeDtypeStruct stand-ins for every model input — the dry-run's batches.

Weak-type-correct, shardable, never allocates.  The modality frontends of
[vlm]/[audio] archs are STUBS: `input_specs` provides precomputed patch/frame
embeddings of shape (B, S, d_model) (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig
from repro.models import lm


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {"labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.input_kind == "embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encdec:
        specs.setdefault("tokens", jax.ShapeDtypeStruct((B, S), jnp.int32))
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.input_kind == "embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encdec:
        specs.setdefault("tokens", jax.ShapeDtypeStruct((B, S), jnp.int32))
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": lm.init_cache_specs(cfg, B, shape.seq_len),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
