"""Training launcher.

    python -m repro.launch.train --arch gemma3-1b --smoke --steps 50
    python -m repro.launch.train --arch llama3-405b --data 16 --model 16 ...

On this CPU box only --smoke scales are runnable; full configs are exercised via
the dry-run (launch/dryrun.py). The loop is the fault-tolerant one (auto-resume,
async checkpoints, SIGTERM-safe, straggler watchdog).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import RULES
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--lam", type=float, default=1e-6)
    ap.add_argument("--emt-mode", default="analog",
                    choices=["ideal", "analog", "bitserial"])
    ap.add_argument("--device", default=None,
                    help="registered technology corner for all layers")
    ap.add_argument("--placement", default=None,
                    help="heterogeneous per-layer placement preset "
                         "(configs PLACEMENTS; overrides --emt-mode/--device)")
    ap.add_argument("--rng", default="hash", choices=["hash", "threefry"])
    ap.add_argument("--rules", default="train_fsdp_tp", choices=list(RULES))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--opt", default="adamw",
                    choices=["adamw", "sgd", "adafactor"])
    args = ap.parse_args()
    if args.placement and args.device:
        ap.error("--placement and --device are mutually exclusive "
                 "(a placement names its corners per layer)")

    if args.placement:
        cfg = get_config(args.arch, rng=args.rng, smoke=args.smoke,
                         placement=args.placement)
    else:
        cfg = get_config(args.arch, emt_mode=args.emt_mode, rng=args.rng,
                         smoke=args.smoke, device=args.device)
    if args.placement:
        from repro.launch.serve import print_plan
        print_plan(cfg)
    if args.smoke:
        cfg = cfg.replace(dtype=jnp.float32)
    mesh = make_host_mesh()
    rules = RULES[args.rules]
    tcfg = TrainConfig(lam=args.lam, lr=args.lr, total_steps=args.steps,
                       opt=OptimizerConfig(name=args.opt))
    step_fn, opt = make_train_step(cfg, tcfg, mesh, rules)
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, d_model=cfg.d_model,
                       input_kind=cfg.input_kind, encdec=cfg.is_encdec)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir,
                      metrics_path=os.path.join(args.ckpt_dir, "metrics.jsonl"))
    state, history = train_loop(state, jitted, data.batch_at, lcfg)
    if history:
        print(f"final: {history[-1]}")


if __name__ == "__main__":
    main()
