"""Serving launcher — batched generation with EMT analog/bit-serial inference.

    python -m repro.launch.serve --arch gemma3-1b --smoke --mode analog
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--mode", default="analog",
                    choices=["ideal", "analog", "bitserial"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    import jax.numpy as jnp
    cfg = get_config(args.arch, emt_mode=args.mode, smoke=args.smoke)
    cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=args.batch,
                        max_len=args.prompt_len + args.max_new)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size,
                                           size=args.prompt_len).astype(np.int32),
                       max_new=args.max_new)
            for _ in range(args.batch)]
    t0 = time.time()
    outs, energy = eng.generate(reqs)
    dt = time.time() - t0
    tok_count = sum(len(o) for o in outs)
    print(f"generated {tok_count} tokens in {dt:.2f}s "
          f"({tok_count/dt:.1f} tok/s), EMT energy {energy*1e-6:.3f} uJ")
    for i, o in enumerate(outs[:2]):
        print(f"  req{i}: {o.tolist()}")


if __name__ == "__main__":
    main()
