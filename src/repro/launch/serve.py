"""Serving launcher — continuous-batching generation with EMT analog/bit-serial
inference.

    python -m repro.launch.serve --arch gemma3-1b --smoke --mode analog \
        --requests 8 --stagger 2 --temperature 0.8 --top-k 40

Flags (new continuous-batching engine):
    --device NAME      run every layer on one registered technology corner
                       (core/device.py registry: pcm, rram, mlc2, mlc4,
                       sram_digital, ...)
    --placement NAME   heterogeneous per-layer placement preset (configs
                       PLACEMENTS, e.g. `mixed`: analog attention on PCM +
                       bit-serial MLPs on RRAM + digital routers); prints the
                       resolved per-layer plan at startup and a per-corner
                       energy report at the end (docs/device_models.md)
    --requests N       total requests to serve (queue beyond --batch backfills)
    --stagger K        submit a new request every K engine steps (0 = all at
                       once, i.e. lockstep-equivalent arrival)
    --temperature/--top-k/--top-p   per-request sampling (seeded per request)
    --eos-id           optional stop token
    --frozen-noise     freeze EMT fluctuation at the engine seed (default:
                       fresh fluctuation every decode step)
    --paged            paged block-table KV cache: slots share a block pool
                       and admission is gated on the free-block budget
    --block-size N     positions per KV block (paged mode)
    --kv-blocks N      global-layer pool size in blocks (default: capacity-
                       equal to the contiguous per-slot regions)
    --kv-ring-blocks N sliding-window-layer pool size in blocks
    --fused-paged-attn / --no-fused-paged-attn
                       paged decode through the fused paged-attention kernel
                       (default on; off = materialized length-clamped gather)
    --paged-attn-impl  kernel dispatch rung: auto (pallas on TPU, jnp ref
                       elsewhere) | pallas | interpret | ref (docs/kernels.md)
    --prefill-chunk N  chunked prefill: prompt tokens admitted per mixed
                       prefill+decode step (attention-only stacks; default 16)
    --no-chunked-prefill
                       force the legacy batch-1 pow2-bucketed prefill path
    --prefix-cache     refcounted prefix caching (needs --paged, an all-global
                       attention stack): shared prompt prefixes are served
                       from resident blocks and bill zero prefill energy
    --draft-placement CORNER
                       heterogeneous speculative decoding: draft k tokens per
                       slot on this (cheap, digital) corner and verify them in
                       one all-lane chunk step on the target placement
                       (greedy-only; docs/control_plane.md)
    --spec-k K         draft tokens proposed per speculative round (default 4)
    --energy-budget-uj B
                       per-request energy SLA: requests exceeding B uJ of
                       billed energy are shed (done_reason="energy_budget")
    --step-budget-uj B rolling per-engine admission bucket: the engine earns
                       B uJ of credit per step; admission head-blocks while
                       the bucket is overdrawn
    --shards N         data-parallel serving over N devices (serve_2d mesh
                       data axis): slots, paged block pools, and the KV cache
                       are partitioned into N shard groups, admission picks
                       the least-occupied shard, decode runs shard-locally.
                       Needs N visible devices — simulate on CPU with
                       XLA_FLAGS=--xla_force_host_platform_device_count=N
                       (docs/serving.md "Multi-device serving")
    --rate R           streaming front-end mode: drive the engine through
                       repro.serve.server.StreamingServer with open-loop
                       Poisson arrivals at R req/s (replaces --stagger) and
                       report p50/p99 TTFT + inter-token latency
    --deadline-s T     per-request deadline in the streaming mode (expired
                       requests retire with done_reason="timeout")
    --max-pending N    bounded admission queue in the streaming mode
                       (arrivals beyond it are rejected — backpressure)

Reports decode tok/s and per-request EMT energy in uJ/token.  With --paged
the startup banner prints which attention path each layer resolved to.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, PLACEMENTS
from repro.models import lm
from repro.models.attention import paged_attn_plan
from repro.nn.param import init_params
from repro.serve.engine import GenRequest, prefill_bucket
from repro.serve.spec import ServeSpec


def print_attn_paths(cfg):
    """Per-layer paged decode-attention path resolution (fused kernel rung or
    gather fallback + why), grouped into runs of equal resolutions."""
    plan = paged_attn_plan(cfg)
    if not plan:
        return
    print(f"paged attention paths ({len(plan)} layers):")
    run = []
    for path, res in plan + [("", "")]:
        if run and res != run[0][1]:
            first, last = run[0][0], run[-1][0]
            span = first if len(run) == 1 else f"{first} .. {last}"
            print(f"  {span:56s} -> {run[0][1]} x{len(run)}")
            run = []
        if path:
            run.append((path, res))


def print_plan(cfg):
    """Resolved per-layer device plan, grouped into runs of equal corners."""
    plan = cfg.placement_plan()
    print(f"device plan ({len(plan)} placement sites):")
    run = []
    for path, corner, mode in plan + (("", "", ""),):
        if run and (corner, mode) != (run[0][1], run[0][2]):
            first, last = run[0][0], run[-1][0]
            span = first if len(run) == 1 else f"{first} .. {last}"
            print(f"  {span:56s} -> {run[0][1]} ({run[0][2]}) x{len(run)}")
            run = []
        if path:
            run.append((path, corner, mode))


def spec_from_args(args) -> ServeSpec:
    """The launcher's CLI flags are thin aliases over :class:`ServeSpec` —
    every knob lands in the shared spec (one validation surface for the
    launcher, the examples, the benches, and the scenario matrix; see
    docs/benchmarks.md)."""
    return ServeSpec(
        arch=args.arch, mode=args.mode, device=args.device,
        placement=args.placement, smoke=args.smoke,
        # speculation needs an all-global stack; the launcher coerces (with
        # a printed notice in main()) instead of refusing
        all_global=bool(args.draft_placement),
        batch_size=args.batch,
        max_len=prefill_bucket(args.prompt_len) + args.max_new,
        seed=args.seed, frozen_noise=args.frozen_noise,
        paged=args.paged, block_size=args.block_size,
        num_blocks=args.kv_blocks, num_ring_blocks=args.kv_ring_blocks,
        fused_paged_attn=args.fused_paged_attn,
        paged_attn_impl=args.paged_attn_impl,
        chunked_prefill=args.chunked_prefill,
        prefill_chunk=args.prefill_chunk,
        prefix_cache=args.prefix_cache,
        draft_placement=args.draft_placement, spec_k=args.spec_k,
        energy_budget_uj=args.energy_budget_uj,
        step_budget_uj=args.step_budget_uj,
        shards=args.shards, max_pending=args.max_pending,
        deadline_s=args.deadline_s,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        eos_id=args.eos_id)


def serve_streaming(eng, reqs, *, rate, deadline_s, max_pending, seed=0):
    """Drive `eng` through the async streaming front-end with open-loop
    Poisson arrivals; returns (results, wall_s, rejected, ttft_s, itl_s)."""
    from repro.serve.scheduler import RejectedError
    from repro.serve.server import StreamingServer

    rng = np.random.default_rng(seed)
    handles, rejected = [], 0
    with StreamingServer(eng, max_pending=max_pending) as srv:
        t0 = time.monotonic()
        at = 0.0
        for r in reqs:
            at += rng.exponential(1.0 / rate)
            delay = t0 + at - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            try:
                handles.append(srv.submit(r, deadline_s=deadline_s))
            except RejectedError:
                rejected += 1
        results = [h.result(timeout=600) for h in handles]
        wall = time.monotonic() - t0
    ttft = [h.ttft_s for h in handles if h.ttft_s is not None]
    itl = [d for h in handles for d in h.itl_s]
    return results, wall, rejected, ttft, itl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--mode", default="analog",
                    choices=["ideal", "analog", "bitserial"])
    ap.add_argument("--device", default=None,
                    help="registered technology corner for all layers "
                         "(pcm, rram, mlc2, mlc4, sram_digital, ...)")
    ap.add_argument("--placement", default=None, choices=list(PLACEMENTS),
                    help="heterogeneous per-layer placement preset "
                         "(overrides --mode/--device: the placement names "
                         "mode and corner per layer)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default: --batch)")
    ap.add_argument("--stagger", type=int, default=0,
                    help="submit one request every K steps (0 = all upfront)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--frozen-noise", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="paged block-table KV cache")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--kv-blocks", type=int, default=None)
    ap.add_argument("--kv-ring-blocks", type=int, default=None)
    ap.add_argument("--fused-paged-attn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paged decode through the fused kernel (default on); "
                         "--no-fused-paged-attn forces the gather fallback")
    from repro.kernels.ops import PAGED_ATTN_IMPLS
    ap.add_argument("--paged-attn-impl", default="auto",
                    choices=list(PAGED_ATTN_IMPLS),
                    help="fused-kernel dispatch rung (docs/kernels.md)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens admitted per mixed prefill+decode "
                         "step (chunked prefill)")
    ap.add_argument("--chunked-prefill", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="force chunked prefill on/off (default: auto — on "
                         "for decoder-only attention stacks)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prefix caching over the paged pool "
                         "(requires --paged + all-global attention)")
    ap.add_argument("--draft-placement", default=None,
                    help="speculative decoding: registered corner for the "
                         "draft placement (e.g. sram_digital); greedy-only")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--energy-budget-uj", type=float, default=None,
                    help="per-request energy SLA in uJ (exceeded -> shed "
                         "with done_reason='energy_budget')")
    ap.add_argument("--step-budget-uj", type=float, default=None,
                    help="per-engine rolling admission budget in uJ/step")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-parallel shard count over the serve_2d mesh "
                         "data axis (needs that many visible devices)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="streaming front-end: open-loop Poisson arrival "
                         "rate in req/s (0 = synchronous --stagger driver)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline for --rate mode")
    ap.add_argument("--max-pending", type=int, default=16,
                    help="admission-queue bound for --rate mode")
    args = ap.parse_args()
    if args.shards > 1 and jax.device_count() < args.shards:
        ap.error(
            f"--shards {args.shards} needs {args.shards} visible devices "
            f"but only {jax.device_count()} present — on CPU simulate "
            f"them with XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={args.shards} (must be set before jax starts)")
    try:
        spec = spec_from_args(args)
        if args.draft_placement:
            # speculation requires an all-global stack (rejected-draft
            # writes would clobber sliding-window ring K/V — see
            # SpeculativeEngine); the spec coerces via all_global, the
            # launcher says so when the stack actually had ring layers
            plain = spec.replace(draft_placement=None, all_global=False,
                                 paged=False, prefix_cache=False)
            c0 = plain.build_config()
            if c0.sliding_window and "local" in c0.blocks():
                print("speculative decoding: coerced attention stack to "
                      "all-global (ring layers are incompatible with "
                      "rejected-draft writes)")
        cfg = spec.build_config()
    except ValueError as e:
        ap.error(str(e))
    print_plan(cfg)
    if args.paged:
        print_attn_paths(cfg)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    n_req = args.requests or args.batch
    eng = spec.build_engine(cfg, params)
    controller = eng.controller
    if args.draft_placement:
        print(f"speculative decoding: draft on {args.draft_placement}, "
              f"k={args.spec_k}")
    print(f"prefill path: "
          f"{'chunked (exact positions, mixed step)' if eng.chunked else 'legacy (batch-1 pow2 buckets)'}"
          + (f", chunk={eng.prefill_chunk}, prefix_cache=on"
             if eng.prefix_cache else
             (f", chunk={eng.prefill_chunk}" if eng.chunked else "")))
    rng = np.random.default_rng(0)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size,
                                           size=args.prompt_len).astype(np.int32),
                       max_new=args.max_new, temperature=args.temperature,
                       top_k=args.top_k, top_p=args.top_p, eos_id=args.eos_id,
                       seed=i, energy_budget_uj=args.energy_budget_uj)
            for i in range(n_req)]

    if args.rate > 0:
        results, dt, rejected, ttft, itl = serve_streaming(
            eng, reqs, rate=args.rate, deadline_s=args.deadline_s,
            max_pending=args.max_pending, seed=args.seed)
        p = lambda xs, q: np.percentile(np.asarray(xs) * 1e3, q)  # noqa: E731
        if ttft:
            print(f"streaming @ {args.rate:g} req/s: TTFT p50 "
                  f"{p(ttft, 50):.1f} ms / p99 {p(ttft, 99):.1f} ms"
                  + (f", inter-token p50 {p(itl, 50):.1f} ms / p99 "
                     f"{p(itl, 99):.1f} ms" if itl else ""))
        if rejected:
            print(f"rejected at admission (queue full): {rejected}")
    else:
        t0 = time.time()
        results = eng.serve(reqs, stagger=args.stagger)
        dt = time.time() - t0

    tok_count = sum(len(r.tokens) for r in results)
    total_uj = sum(r.energy_pj for r in results) * 1e-6
    print(f"served {len(results)} requests / {tok_count} tokens in {dt:.2f}s "
          f"({tok_count/dt:.1f} tok/s), EMT energy {total_uj:.3f} uJ "
          f"({total_uj/max(tok_count,1):.4f} uJ/token)")
    if eng.kv_reads_total:
        print(f"decode KV reads: {eng.kv_reads_total:.3g} elements "
              f"({eng.kv_reads_total/max(tok_count,1):.3g}/token; "
              f"mask-visible positions only — masked/padded positions "
              f"are free)")
    if eng.chunked:
        line = f"prefill tokens computed: {eng.prefill_tokens_total}"
        if eng.prefix_cache:
            parked = sum(p.num_cached for p in eng.kv.pools_g)
            line += (f", served from prefix cache: "
                     f"{eng.cached_prefix_tokens} "
                     f"(hits {eng.kv.prefix_hits}, "
                     f"evictions {eng.kv.prefix_evictions}, "
                     f"{parked} blocks parked)")
            if eng.n_shards > 1:
                line += (f", cross-shard misses "
                         f"{eng.kv.cross_shard_prefix_misses}")
        print(line)
    if eng.n_shards > 1:
        occ = eng.shard_occupancy
        bal = float(occ.min()) / max(float(occ.max()), 1.0)
        s_uj = [round(float(v) * 1e-6, 3) for v in eng.shard_energy_pj]
        s_idle = [round(float(v) * 1e-6, 3) for v in eng.shard_idle_energy_pj]
        print(f"shards ({eng.n_shards} x batch {eng.shard_size}): "
              f"occupancy {occ.tolist()} (balance {bal:.2f}), "
              f"energy {s_uj} uJ, idle {s_idle} uJ")
    for r in results[:4]:
        per_tok = r.energy_pj * 1e-6 / max(len(r.tokens), 1)
        print(f"  req{r.rid}: {len(r.tokens)} toks, {per_tok:.4f} uJ/token, "
              f"{r.done_reason}: {r.tokens[:6].tolist()}")
    if args.draft_placement:
        shed = sum(1 for r in results if r.done_reason == "energy_budget")
        print(f"speculation: accept rate {eng.accept_rate:.2f}, "
              f"accepted-length histogram {eng.accept_len_hist.tolist()}, "
              f"draft energy {eng.draft_total_energy_pj*1e-6:.3f} uJ "
              f"({eng.draft_total_energy_pj/max(eng.total_energy_pj,1e-12)*100:.1f}% of total)"
              + (f", shed {shed}" if shed else ""))
    if controller is not None:
        print(f"control plane: shed {controller.shed} on request budgets, "
              f"deferred {controller.deferred_steps} admissions on the "
              f"engine bucket")
    if eng.corner_energy_pj:
        from repro.analysis.report import corner_table
        print("per-corner energy:")
        print(corner_table(eng.corner_energy_pj, tokens=tok_count))


if __name__ == "__main__":
    main()
