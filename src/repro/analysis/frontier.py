"""Trade-off frontier reduction over scenario-matrix cells.

The paper's central claim is a *frontier*, not a point: accuracy vs energy
efficiency under EMT instability (Fig. 9's traditional/A/A+B/A+B+C sweep),
and — once the network is serving — decode throughput joins the trade as the
third axis.  The matrix executor (benchmarks/matrix.py) emits one metrics
dict per scenario cell; this module reduces those cells into the **Pareto
frontier** over

* ``decode_tok_per_s``  — higher is better (wall-clock, machine-dependent;
  the frontier *membership* is what regressions gate on, not the values),
* ``uj_per_token``      — lower is better (analytic EMT energy, exact),
* ``accuracy_proxy``    — higher is better (ablation-harness deployment
  accuracy of the cell's worst device corner; cells sharing an EMT surface
  share the value).

Cells are grouped by ``emt_label`` (the placement preset / pinned corner /
single-corner mode) so the report answers the question the paper asks:
*which placement wins at which operating point* — a frontier with one group
collapsed to a dot means that placement is dominated everywhere.

``frontier_report(cells)`` returns the JSON section stored under
``BENCH_serve.json::matrix`` (per-group Pareto sets + dominated counts);
``frontier_markdown(section)`` renders the human-readable table CI uploads
as an artifact.  ``pareto_front`` is deliberately generic (maximize tuples)
so gates and tests can recompute membership from the raw cells and compare.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Sequence, Tuple

# metric key -> +1 maximize / -1 minimize; order fixes the report columns
FRONTIER_AXES: Tuple[Tuple[str, int], ...] = (
    ("decode_tok_per_s", +1),
    ("uj_per_token", -1),
    ("accuracy_proxy", +1),
)


def _score(cell: dict) -> Tuple[float, ...]:
    """The maximize-tuple for one cell's metrics (missing axis -> -inf, so a
    cell that failed to produce a metric can never enter the frontier)."""
    out = []
    for key, sign in FRONTIER_AXES:
        v = cell.get(key)
        out.append(-math.inf if v is None or not math.isfinite(float(v))
                   else sign * float(v))
    return tuple(out)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff maximize-tuple `a` is >= `b` everywhere and > somewhere."""
    return all(x >= y for x, y in zip(a, b)) and any(
        x > y for x, y in zip(a, b))


def pareto_front(scores: Iterable[Sequence[float]]) -> List[int]:
    """Indices of the non-dominated members (maximize every coordinate).

    Duplicated points all stay on the front (neither strictly dominates);
    O(n^2) — matrix runs are tens of cells, not millions.
    """
    pts = [tuple(s) for s in scores]
    return [i for i, p in enumerate(pts)
            if not any(dominates(q, p) for j, q in enumerate(pts) if j != i)]


def frontier_report(cells: List[dict]) -> dict:
    """Reduce executor cell metrics into the ``matrix`` frontier section.

    Each cell dict needs ``name``, ``emt_label`` and the FRONTIER_AXES
    metrics.  Returns ``{"axes", "groups": {label: {"cells", "pareto",
    "dominated"}}, "pareto_names"}`` — `pareto` lists cell names in frontier
    order (descending tok/s), `pareto_names` is the flat union the
    non-regression gate diffs against.
    """
    groups: Dict[str, List[dict]] = {}
    for c in cells:
        groups.setdefault(str(c.get("emt_label", "default")), []).append(c)
    out_groups = {}
    for label, members in sorted(groups.items()):
        front = set(pareto_front([_score(c) for c in members]))
        pareto = sorted((members[i] for i in front),
                        key=lambda c: -(c.get("decode_tok_per_s") or 0.0))
        out_groups[label] = {
            "cells": len(members),
            "pareto": [c["name"] for c in pareto],
            "dominated": sorted(c["name"] for i, c in enumerate(members)
                                if i not in front),
        }
    return {
        "axes": [{"metric": k, "goal": "max" if s > 0 else "min"}
                 for k, s in FRONTIER_AXES],
        "groups": out_groups,
        "pareto_names": sorted({n for g in out_groups.values()
                                for n in g["pareto"]}),
    }


def frontier_markdown(cells: List[dict], section: dict) -> str:
    """Human-readable frontier table (the CI artifact): one row per cell,
    frontier members starred, grouped by emt_label."""
    by_name = {c["name"]: c for c in cells}
    rows = ["| group | cell | front | tok/s | uJ/token | acc proxy |",
            "|" + "---|" * 6]

    def fmt(v, nd):
        return "-" if v is None else f"{float(v):.{nd}f}"

    for label, g in sorted(section["groups"].items()):
        names = g["pareto"] + g["dominated"]
        for n in names:
            c = by_name.get(n, {})
            star = "*" if n in g["pareto"] else ""
            rows.append(f"| {label} | {n} | {star} | "
                        f"{fmt(c.get('decode_tok_per_s'), 1)} | "
                        f"{fmt(c.get('uj_per_token'), 5)} | "
                        f"{fmt(c.get('accuracy_proxy'), 4)} |")
    return "\n".join(rows)
