"""Optimized-HLO text analysis: collective bytes, op census, loop detection.

Input is ``compiled.as_text()`` — the *post-SPMD-partitioning* per-device program,
so every parsed payload is a per-chip quantity.  Conventions (DESIGN.md §7):

* bytes counted are the **operand** sizes entering each collective:
    - all-reduce / all-to-all / collective-permute: operand == output shape
    - all-gather: operand == output / group_size (each chip contributes a shard)
    - reduce-scatter: operand == output * group_size
* dry-run graphs are loop-free by construction; any residual `while` op makes the
  analysis untrustworthy and is surfaced as ``num_while`` (asserted 0 upstream).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_WHILE_RE = re.compile(r"=\s+(\([^)]*\)|\S+)\s+while\(")


def shape_bytes(shape_str: str) -> float:
    """bytes of 'f32[128,256]' or a '(f32[..], s32[..])' tuple string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def analyze_collectives(hlo_text: str) -> dict:
    """Sum per-chip collective operand bytes by op type; census + diagnostics."""
    by_type = defaultdict(float)
    count = defaultdict(int)
    top = []
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                      # async pair: count the -start only
        shape_str, op = m.group(1), m.group(2)
        out_bytes = shape_bytes(shape_str)
        g = _group_size(line)
        if op == "all-gather":
            operand = out_bytes / g
        elif op == "reduce-scatter":
            operand = out_bytes * g
        else:
            operand = out_bytes
        by_type[op] += operand
        count[op] += 1
        top.append((operand, op, shape_str.strip()[:80], g))
    top.sort(reverse=True)
    return {
        "collective_bytes_per_chip": float(sum(by_type.values())),
        "bytes_by_type": {k: float(v) for k, v in by_type.items()},
        "count_by_type": dict(count),
        "top_collectives": [
            {"bytes": float(b), "op": o, "shape": s, "group": g}
            for b, o, s, g in top[:12]],
        "num_while": len(_WHILE_RE.findall(hlo_text)),
    }
