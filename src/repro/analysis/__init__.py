from repro.analysis.hlo import analyze_collectives, shape_bytes
from repro.analysis.roofline import Roofline, model_flops, active_params
