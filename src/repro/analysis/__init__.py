from repro.analysis.hlo import analyze_collectives, shape_bytes
from repro.analysis.roofline import Roofline, model_flops, active_params
from repro.analysis.frontier import (FRONTIER_AXES, dominates, pareto_front,
                                     frontier_report, frontier_markdown)
