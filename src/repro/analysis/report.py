"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON cache.

    PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="16x16"):
    rows = []
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful | roofline_frac | peak+args GB/chip | note |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted([r for r in recs if r.get("status") == "ok"
                     and r["mesh"] == mesh],
                    key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        ro = r["roofline"]
        note = _note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['dominant'].replace('_s','')} | "
            f"{ro['useful_flops_ratio']:.3f} | {ro['roofline_fraction']:.3f} | "
            f"{fmt_bytes(r['temp_bytes_per_chip'] + r['arg_bytes_per_chip'])} | "
            f"{note} |")
    return "\n".join(rows)


def _note(r):
    ro = r["roofline"]
    dom = ro["dominant"]
    by = r.get("bytes_by_type", {})
    top = max(by, key=by.get) if by else "-"
    if dom == "collective_s":
        return f"cut {top} traffic (top op {by[top]/2**30:.1f} GB/chip)"
    if dom == "memory_s":
        return "reduce HBM traffic: fuse noise-gen, bf16 residuals, less remat"
    return "MXU-bound: raise per-chip batch or reduce sim overhead"


def corner_table(corner_energy_pj: dict, tokens: int = 0) -> str:
    """Per-device-corner EMT energy breakdown (heterogeneous placements).

    `corner_energy_pj`: {corner label: pJ} — the engine's `corner_energy_pj`
    accumulator or an aux tree's `{name: c["energy_pj"]}`.  Rows are sorted by
    energy; the total line is the exact sum (per-corner accounting books every
    crossbar read under exactly one corner)."""
    total = sum(corner_energy_pj.values())
    hdr = "| corner | energy uJ | share |" + (" uJ/token |" if tokens else "")
    rows = [hdr, "|" + "---|" * (4 if tokens else 3)]
    for name, pj in sorted(corner_energy_pj.items(), key=lambda kv: -kv[1]):
        row = (f"| {name} | {pj * 1e-6:.4f} | "
               f"{pj / total if total else 0.0:6.1%} |")
        if tokens:
            row += f" {pj * 1e-6 / tokens:.5f} |"
        rows.append(row)
    row = f"| total | {total * 1e-6:.4f} | 100.0% |"
    if tokens:
        row += f" {total * 1e-6 / max(tokens, 1):.5f} |"
    rows.append(row)
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile_s | while | "
            "collectives (AR/AG/RS/A2A/CP) | coll GB/chip |",
            "|" + "---|" * 8]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | - | - | - | - |")
            continue
        c = r.get("count_by_type", {})
        counts = "/".join(str(c.get(k, 0)) for k in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f} | {r['num_while']} | {counts} | "
            f"{r['collective_bytes_per_chip']/2**30:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir)
    ok = [r for r in recs if r.get("status") == "ok"]
    print(f"## cells: {len(ok)} ok / {len(recs)} total\n")
    print("### Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n### Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n### Dry-run census\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
