"""Roofline model for TPU v5e (the target hardware; this box only compiles).

    compute_s    = HLO_FLOPs_global   / (chips * 197e12)     [bf16 MXU peak]
    memory_s     = HLO_bytes_global   / (chips * 819e9)      [HBM]
    collective_s = collective_bytes_global / (chips * 50e9)  [per-link ICI]

``cost_analysis``/HLO parsing yield *per-chip* numbers (spike-verified); globals
are per-chip x chips, so the chips cancel — the terms are per-chip seconds. The
dominant term is the bottleneck; `model_flops / hlo_flops` measures how much of
the compiled compute is algorithmically useful (remat / noise-sim / dispatch
overheads show up here).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """Analytic useful FLOPs per step: 6*N*D train, 2*N*D forward."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_params(cfg, n_params: int, spec_tree=None) -> int:
    """Params touched per token (MoE: k of E experts)."""
    if cfg.num_experts and cfg.experts_per_token:
        # expert share of parameters
        E, K = cfg.num_experts, cfg.experts_per_token
        F = cfg.moe_d_ff or cfg.d_ff
        n_moe_layers = sum(cfg.moe_layer_mask())
        expert_params = n_moe_layers * E * 3 * cfg.d_model * F
        return int(n_params - expert_params * (1 - K / E))
    return n_params


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    chips: int
    model_flops_global: float

    def terms(self) -> dict:
        compute_s = self.flops_per_chip / PEAK_FLOPS
        memory_s = self.bytes_per_chip / HBM_BW
        coll_s = self.coll_bytes_per_chip / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": coll_s}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        hlo_flops_global = self.flops_per_chip * self.chips
        useful = (self.model_flops_global / hlo_flops_global
                  if hlo_flops_global else 0.0)
        # roofline fraction: useful-compute time / bound time (how close the
        # step is to the compute roofline if overheads vanished)
        ideal_s = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return dict(
            terms,
            dominant=dom,
            step_time_lower_bound_s=bound,
            model_flops_global=self.model_flops_global,
            hlo_flops_global=hlo_flops_global,
            useful_flops_ratio=useful,
            ideal_compute_s=ideal_s,
            roofline_fraction=(ideal_s / bound) if bound > 0 else 0.0,
        )
