"""Deterministic synthetic data pipelines (no external datasets on this box).

* ``SyntheticLM`` — learnable token streams: an affine Markov chain over the vocab
  with injected noise, so cross-entropy demonstrably decreases during training.
  Deterministic in (seed, step, host_shard) — resumable from any checkpointed step
  and shardable across hosts (each host generates only its batch slice).
* ``SyntheticImages`` — class-conditional structured images for the paper's CNN
  experiments: per-class frequency patterns + Gaussian noise; linearly separable
  enough that accuracy trends (paper Fig. 9/10 orderings) are measurable.
* the *device-enhanced* part of the dataset (technique A) is the fluctuation
  stream: fresh RTN states per step, keyed by the training step — realized inside
  the model through Ctx.seed (see DESIGN.md §3.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int                  # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    input_kind: str = "tokens"       # tokens | embeds
    d_model: int = 0                 # for embeds stubs
    encdec: bool = False

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (resume-safe)."""
        rng = np.random.default_rng(
            (self.seed, step, self.host_id, 0xE17))
        V = self.vocab_size
        B, S = self.batch_size, self.seq_len
        a = 31 % V or 1
        b = rng.integers(0, V)
        x0 = rng.integers(0, V, size=(B, 1))
        toks = [x0]
        for _ in range(S):
            nxt = (toks[-1] * a + b) % V
            flip = rng.random((B, 1)) < 0.1
            nxt = np.where(flip, rng.integers(0, V, size=(B, 1)), nxt)
            toks.append(nxt)
        seq = np.concatenate(toks, axis=1).astype(np.int32)   # (B, S+1)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if self.input_kind == "embeds":
            emb = rng.standard_normal((B, S, self.d_model)).astype(np.float32)
            batch["embeds"] = emb
        if self.encdec:
            batch["enc_embeds"] = rng.standard_normal(
                (B, S, self.d_model)).astype(np.float32)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticImages:
    num_classes: int = 4
    image_size: int = 16
    channels: int = 3
    noise: float = 0.25
    seed: int = 0

    def _pattern(self, cls):
        """Per-class deterministic frequency pattern."""
        s = self.image_size
        yy, xx = np.mgrid[0:s, 0:s] / s
        freq = 1 + cls % 3
        phase = cls * 0.7
        base = np.sin(2 * np.pi * freq * xx + phase) * \
            np.cos(2 * np.pi * (1 + cls // 3) * yy)
        img = np.stack([base, base.T, base * base.T], -1)
        return 0.5 + 0.4 * img

    def batch(self, batch_size: int, step: int, split: str = "train") -> dict:
        salt = 0 if split == "train" else 0x7E57
        rng = np.random.default_rng((self.seed, step, salt))
        labels = rng.integers(0, self.num_classes, size=batch_size)
        imgs = np.stack([self._pattern(c) for c in labels]).astype(np.float32)
        imgs += rng.standard_normal(imgs.shape).astype(np.float32) * self.noise
        return {"images": np.clip(imgs, 0, 1),
                "labels": labels.astype(np.int32)}
