from repro.data.synthetic import SyntheticLM, SyntheticImages
