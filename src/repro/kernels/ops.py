"""Jit'd public wrappers around the Pallas kernels.

Handle: leading-dim flattening, padding to MXU-aligned tiles, block-size selection,
and dispatch (TPU pallas / interpret-mode pallas / jnp reference).  All wrappers are
shape-polymorphic at the Python level and jit-stable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.device import DeviceModel
from repro.kernels import ref as kref
from repro.kernels.emt_matmul import emt_matmul_pallas
from repro.kernels.emt_bitserial import emt_bitserial_pallas
from repro.kernels.paged_attention import (NEG_INF, paged_attention_pallas,
                                           paged_attention_decode_pallas)
from repro.kernels.paged_prefill import paged_prefill_pallas


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pick_blocks(m, k, n, *, vmem_budget=8 * 2 ** 20, dtype_bytes=4, bits=1):
    """Choose (bm, bn, bk) multiples of 128 that keep the working set in VMEM.

    Working set per grid step ≈ bm*bk (x) + bk*bn (w) + bm*bn (fp32 acc) + noise
    regs. We prefer large bk (fewer revisits of the accumulator) then bm.
    """
    bm = min(512, max(128, 128 * (m // 128 or 1)))
    bn = 128
    bk = 128
    def ws(bm, bn, bk):
        return dtype_bytes * (bm * bk + bk * bn + bm * bn)
    while ws(bm, bn, bk * 2) <= vmem_budget and bk * 2 <= k and k % (bk * 2) == 0:
        bk *= 2
    while ws(bm, bn * 2, bk) <= vmem_budget and bn * 2 <= n and n % (bn * 2) == 0:
        bn *= 2
    while ws(bm, bn, bk) > vmem_budget and bm > 128:
        bm //= 2
    return int(min(bm, m)), int(bn), int(bk)


@partial(jax.jit, static_argnames=("device", "seed_static", "plane", "interpret",
                                   "use_ref"))
def emt_matmul(x, w, rho, *, device: DeviceModel, seed_static: int = 0, plane=0,
               interpret=False, use_ref=False):
    """Noisy crossbar matmul: x (..., K) @ w (K, N) with in-kernel RTN noise."""
    lead = x.shape[:-1]
    kdim, n = w.shape
    x2 = x.reshape(-1, kdim)
    if use_ref:
        y = kref.emt_matmul_ref(x2, w, rho, device=device, seed=seed_static,
                                plane=plane)
        return y.reshape(*lead, n)
    m = x2.shape[0]
    bm, bn, bk = pick_blocks(m, kdim, n)
    xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    y = emt_matmul_pallas(xp, wp, rho, device=device, seed=seed_static, plane=plane,
                          bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n].reshape(*lead, n)


@partial(jax.jit, static_argnames=("device", "bits", "seed_static", "base_plane",
                                   "interpret", "use_ref"))
def _bitserial_jit(xq, w, rho, *, device: DeviceModel, bits: int,
                   seed_static: int, base_plane: int, interpret: bool,
                   use_ref: bool):
    lead = xq.shape[:-1]
    kdim, n = w.shape
    x2 = xq.reshape(-1, kdim)
    if use_ref:
        y = kref.emt_bitserial_ref(x2, w, rho, device=device, bits=bits,
                                   seed=seed_static, base_plane=base_plane)
        return y.reshape(*lead, n)
    m = x2.shape[0]
    bm, bn, bk = pick_blocks(m, kdim, n, bits=bits)
    xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    y = emt_bitserial_pallas(xp, wp, rho, device=device, bits=bits,
                             seed=seed_static, base_plane=base_plane,
                             bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n].reshape(*lead, n)


PAGED_ATTN_IMPLS = ("auto", "pallas", "interpret", "ref")


def pick_block_chunk(width: int, block_size: int, *, head_dim: int = 128,
                     dtype_bytes: int = 4, vmem_budget: int = 4 * 2 ** 20):
    """Blocks streamed per grid step of the paged attention/prefill kernels.

    Occupancy-aware: ``width`` is the (clamped) block-table width — the
    serving engine shrinks it each step to the block-rounded bucket of the
    furthest live position (lm.clamped_lens), so table width tracks cache
    occupancy.  Low occupancy -> narrow table -> the whole view fits one
    grid step (no online-softmax corrections, no double-buffer churn); a
    full table walks in ~512-position chunks — large enough to amortize the
    recurrence and keep the MXU fed per score matmul, small enough that the
    double buffer (2 slots x K+V tiles) stays well inside the VMEM budget.

    Returns a power of two so padded table widths stay minimal.
    """
    if width <= 0:
        return 1
    # positions the VMEM budget allows per slot-pair: 2 slots x 2 arrays
    pos_budget = max(block_size, vmem_budget // (4 * head_dim * dtype_bytes))
    span_cap = max(block_size, min(512, pos_budget))
    cpb = max(1, span_cap // block_size)
    cpb = 1 << (cpb.bit_length() - 1)                  # floor to pow2
    width_pow2 = 1 << (int(width) - 1).bit_length()    # ceil to pow2
    return int(min(cpb, width_pow2))


def _pad_view(table, mask, k_pool, cpb):
    """Pad the block table (zero block) and mask rows (NEG_INF) to a
    block-chunk multiple — padded chunks read the zero block and contribute
    exact zeros."""
    T = table.shape[1]
    pad = (-T) % cpb
    if pad:
        zero_blk = k_pool.shape[0] - 1
        table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=zero_blk)
        if mask is not None:
            bs = k_pool.shape[1]
            mask = jnp.pad(mask, ((0, 0), (0, pad * bs)),
                           constant_values=NEG_INF)
    return table, mask


def default_paged_impl() -> str:
    """Resolve the "auto" paged-attention impl for this process: compiled
    pallas on TPU, the jnp reference elsewhere (interpret mode is an
    emulator — correct everywhere, fast nowhere; tests opt into it)."""
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@partial(jax.jit, static_argnames=("softcap", "impl"))
def paged_attention(q, k_pool, v_pool, table, mask, *, softcap=0.0,
                    impl="ref"):
    """Fused paged-attention decode — jit-stable wrapper + dispatch.

    q (B, KV, G, hd) post-RoPE query token per row; k_pool/v_pool
    (num_blocks + 1, block_size, KV, hd) serving pools (zero block last);
    table (B, T) int32 block rows (possibly length-clamped); mask (B, L)
    additive fp32 over logical positions, L <= T * block_size.

    The wrapper pads the mask rows up to the block-rounded width T*bs with
    NEG_INF (a ring shorter than one block, say window 8 paged at
    block_size 16, leaves a partial last chunk) — padded lanes read whatever
    the block holds and contribute exact zeros.  Returns (B, KV, G, hd) fp32.
    """
    if impl not in PAGED_ATTN_IMPLS:
        raise ValueError(f"unknown paged-attention impl {impl!r}; "
                         f"known: {PAGED_ATTN_IMPLS}")
    bs = k_pool.shape[1]
    T = table.shape[1]
    L = mask.shape[1]
    assert L <= T * bs, f"mask rows ({L}) exceed the table view ({T}x{bs})"
    mask = mask.astype(jnp.float32)
    if L < T * bs:                           # partial last block: mask it out
        mask = jnp.pad(mask, ((0, 0), (0, T * bs - L)),
                       constant_values=NEG_INF)
    if impl == "ref" or (impl == "auto" and default_paged_impl() == "ref"):
        out = kref.paged_attention_ref(q, k_pool, v_pool, table, mask,
                                       softcap=softcap)
        # Materialization point, matching what the pallas custom-call is on
        # TPU.  Without it XLA (CPU) fuses the reference's masked-softmax
        # arithmetic into downstream reductions (e.g. the EMT DAC per-tensor
        # max) and fully-masked rows (zero-length-encoder slots) come back
        # NaN — the de-optimized graph is clean, so this is purely an XLA
        # rewrite hazard (tests/test_paged_attention.py enc-dec harness).
        return jax.lax.optimization_barrier(out)
    cpb = pick_block_chunk(T, bs, head_dim=q.shape[-1])
    table, mask = _pad_view(table, mask, k_pool, cpb)
    return paged_attention_pallas(q, k_pool, v_pool, table, mask,
                                  softcap=softcap, block_chunk=cpb,
                                  interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("softcap", "impl"))
def paged_attention_decode(q, k_pool, v_pool, table, mask, k_new, v_new,
                           wpos, active, *, softcap=0.0, impl="ref"):
    """One-launch decode: fused KV cache write + paged attention.

    On top of :func:`paged_attention`: k_new/v_new (B, KV, hd) are the
    step's new K/V rows and ``wpos`` (B,) int32 the per-row absolute (or
    ring-wrapped) write position — row b writes them at
    ``pool[table[b, wpos[b] // bs], wpos[b] % bs]`` before attending, iff
    ``active[b]`` (None => all rows write).  The mask rows must already make
    the written position visible (the decode mask does: position index is
    causally visible to itself).

    Returns (out (B, KV, G, hd) fp32, k_pool, v_pool) — the returned pools
    ARE the update (pallas rungs alias them onto the inputs via
    input_output_aliases; the ref rung scatters functionally), bit-identical
    to the legacy scatter-then-attend pair (`attention._paged_write` +
    gather/attend): same cast, same drop semantics for inactive rows.
    """
    if impl not in PAGED_ATTN_IMPLS:
        raise ValueError(f"unknown paged-attention impl {impl!r}; "
                         f"known: {PAGED_ATTN_IMPLS}")
    B = q.shape[0]
    bs = k_pool.shape[1]
    T = table.shape[1]
    L = mask.shape[1]
    assert L <= T * bs, f"mask rows ({L}) exceed the table view ({T}x{bs})"
    mask = mask.astype(jnp.float32)
    if L < T * bs:
        mask = jnp.pad(mask, ((0, 0), (0, T * bs - L)),
                       constant_values=NEG_INF)
    wpos = jnp.asarray(wpos, jnp.int32)
    wblk = jnp.take_along_axis(table, (wpos // bs)[:, None], axis=1)[:, 0]
    wblk = wblk.astype(jnp.int32)
    woff = (wpos % bs).astype(jnp.int32)
    wok = (jnp.ones((B,), jnp.int32) if active is None
           else jnp.asarray(active).astype(jnp.int32))
    k_new = k_new.astype(k_pool.dtype)
    v_new = v_new.astype(v_pool.dtype)
    if impl == "ref" or (impl == "auto" and default_paged_impl() == "ref"):
        out, k_pool, v_pool = kref.paged_attention_decode_ref(
            q, k_pool, v_pool, table, mask, k_new, v_new, wblk, woff, wok,
            softcap=softcap)
        # same XLA CPU rewrite hazard as paged_attention (see above)
        return jax.lax.optimization_barrier((out, k_pool, v_pool))
    cpb = pick_block_chunk(T, bs, head_dim=q.shape[-1])
    table, mask = _pad_view(table, mask, k_pool, cpb)
    return paged_attention_decode_pallas(
        q, k_pool, v_pool, table, mask, k_new, v_new, wblk, woff, wok,
        softcap=softcap, block_chunk=cpb, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("softcap", "impl"))
def paged_prefill(q, k_pool, v_pool, table, qpos, *, softcap=0.0,
                  impl="ref"):
    """Flash-style chunked prefill through the block table.

    q (B, C, H, hd) post-RoPE query chunk (the chunk's K/V must already be
    written to the pools — write-then-attend, like the legacy path);
    qpos (B, C) int32 absolute per-lane query positions, padding lanes
    clamped to the row's last real lane (lm.chunk_step's convention).
    Causality is derived from qpos — no materialized mask.

    Returns (B, C, H * hd) fp32 — the `_gqa_core` output contract, sans the
    final cache-dtype cast (the caller owns it).
    """
    if impl not in PAGED_ATTN_IMPLS:
        raise ValueError(f"unknown paged-attention impl {impl!r}; "
                         f"known: {PAGED_ATTN_IMPLS}")
    B, C, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    bs = k_pool.shape[1]
    T = table.shape[1]
    # regroup (B, C, H, hd) -> (B, KV, C*G, hd): kv head to a grid axis,
    # chunk lanes x group heads fused into the query-tile rows (row c*G + g)
    qt = q.reshape(B, C, KV, G, hd).transpose(0, 2, 1, 3, 4)
    qt = qt.reshape(B, KV, C * G, hd)
    qpe = jnp.repeat(jnp.asarray(qpos, jnp.int32), G, axis=1)   # (B, C*G)
    if impl == "ref" or (impl == "auto" and default_paged_impl() == "ref"):
        out = kref.paged_prefill_ref(qt, k_pool, v_pool, table, qpe,
                                     softcap=softcap)
        out = jax.lax.optimization_barrier(out)
    else:
        cpb = pick_block_chunk(T, bs, head_dim=hd)
        table, _ = _pad_view(table, None, k_pool, cpb)
        qlast = jnp.max(qpe, axis=1).astype(jnp.int32)
        out = paged_prefill_pallas(qt, k_pool, v_pool, table, qpe, qlast,
                                   softcap=softcap, block_chunk=cpb,
                                   interpret=(impl == "interpret"))
    out = out.reshape(B, KV, C, G, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, C, H * hd)


def emt_bitserial_matmul(xq, w, rho, *, device: DeviceModel, bits=7, seed=0,
                         base_plane=0, interpret=False, use_ref=False):
    """Bit-serial decomposed noisy matmul (technique C). xq: integer-valued levels."""
    return _bitserial_jit(xq, w, rho, device=device, bits=bits,
                          seed_static=int(seed) if not hasattr(seed, "dtype") else 0,
                          base_plane=base_plane, interpret=interpret, use_ref=use_ref)
