"""Pure-jnp oracles for the Pallas kernels — bit-exact by construction.

The kernels sample RTN states from global element coordinates through
:mod:`repro.core.hashrng`; these references do the same over the un-tiled arrays, so
(kernel, reference) pairs agree to fp32 accumulation order.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hashrng
from repro.core.device import DeviceModel
from repro.core.decompose import bit_plane
from repro.kernels.paged_attention import NEG_INF


def emt_matmul_ref(x, w, rho, *, device: DeviceModel, seed=0, plane=0):
    """Oracle for kernels.emt_matmul.emt_matmul_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    offs = hashrng.tile_state_offsets(
        seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs, plane=plane)
    wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
    return jnp.matmul(x, wn, preferred_element_type=jnp.float32).astype(jnp.float32)


def paged_attention_ref(q, k_pool, v_pool, table, mask, *, softcap=0.0):
    """Oracle for kernels.paged_attention.paged_attention_pallas.

    One-shot masked softmax over the table-gathered view — mathematically
    identical to the kernel's online-softmax chunk walk (parity is ulp-level:
    accumulation order differs), with the kernel's masking semantics: a row
    with no visible lane yields exact zeros, fully-masked lanes contribute
    exact zeros.  q (B, KV, G, hd); pools (NB+1, bs, KV, hd); table (B, T)
    int32; mask (B, T*bs) additive fp32.  Returns (B, KV, G, hd) fp32.

    This rung is also the production decode path on CPU hosts (ops.py "auto"
    dispatch), so it is written for speed there: one fused gather of the
    *length-clamped* view (the serving engine clamps `table`/`mask` to the
    live block-rounded bucket, not max_len) + one dense attend.  The
    never-materialize-the-view property belongs to the pallas rung, where
    the view would otherwise round-trip through HBM per layer per step.
    """
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    T = table.shape[1]
    L = T * bs
    scale = 1.0 / np.sqrt(hd)
    kv = k_pool[table].reshape(B, L, KV, hd)           # (B, T, bs, ...) flat
    vv = v_pool[table].reshape(B, L, KV, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q, kv,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + mask[:, None, None, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    # m_safe keeps the exp argument away from sentinel-minus-sentinel
    # differences on all-masked rows (exact in strict fp, NaN-prone under
    # XLA's reassociating fusions inside larger jitted graphs)
    m_safe = jnp.where(m > NEG_INF / 2, m, 0.0)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
    acc = jnp.einsum("bkgs,bskh->bkgh", p.astype(vv.dtype), vv,
                     preferred_element_type=jnp.float32)
    return acc / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def emt_bitserial_ref(xq, w, rho, *, device: DeviceModel, bits=7, seed=0,
                      base_plane=0):
    """Oracle for kernels.emt_bitserial.emt_bitserial_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    sign = jnp.sign(xq.astype(jnp.float32))
    mag = jnp.abs(xq.astype(jnp.float32))
    acc = jnp.zeros((*xq.shape[:-1], n), jnp.float32)
    for p in range(bits):
        offs = hashrng.tile_state_offsets(
            seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs,
            plane=base_plane + p)
        wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
        planes = (sign * bit_plane(mag, p)).astype(w.dtype)
        acc = acc + (2.0 ** p) * jnp.matmul(
            planes, wn, preferred_element_type=jnp.float32).astype(jnp.float32)
    return acc
