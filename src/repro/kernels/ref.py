"""Pure-jnp oracles for the Pallas kernels — bit-exact by construction.

The kernels sample RTN states from global element coordinates through
:mod:`repro.core.hashrng`; these references do the same over the un-tiled arrays, so
(kernel, reference) pairs agree to fp32 accumulation order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashrng
from repro.core.device import DeviceModel
from repro.core.decompose import bit_plane
from repro.kernels.paged_attention import NEG_INF


def emt_matmul_ref(x, w, rho, *, device: DeviceModel, seed=0, plane=0):
    """Oracle for kernels.emt_matmul.emt_matmul_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    offs = hashrng.tile_state_offsets(
        seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs, plane=plane)
    wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
    return jnp.matmul(x, wn, preferred_element_type=jnp.float32).astype(jnp.float32)


def _bmm_masked_attend(q, kv, vv, mask_rows, *, softcap=0.0):
    """Batched-GEMM masked one-shot softmax attend.

    q (B, KV, R, hd) query rows per kv head; kv/vv (B, L, KV, hd) logical
    views; mask_rows (B, R, L) or (B, 1, L) additive fp32.  Returns
    (B, KV, R, hd) fp32.

    The contraction runs in `lax.dot_general` batched-matmul layout — K/V
    transposed to (B*KV, L, hd) — which XLA:CPU lowers to its tuned batch-GEMM
    (the `bkgh,bskh` einsum form lowers to a loop-of-dots and was measured
    ~20% slower end-to-end on the decode rung; see BENCH_kernels.json).
    Masking semantics match the pallas kernels: a row with no visible lane
    yields exact zeros, masked lanes contribute exact zeros (m_safe keeps the
    exp argument away from sentinel-minus-sentinel differences — exact in
    strict fp, NaN-prone under XLA's reassociating fusions inside larger
    jitted graphs).
    """
    B, KV, R, hd = q.shape
    L = kv.shape[1]
    scale = 1.0 / np.sqrt(hd)
    k2 = kv.transpose(0, 2, 1, 3).reshape(B * KV, L, hd)
    v2 = vv.transpose(0, 2, 1, 3).reshape(B * KV, L, hd)
    q2 = q.reshape(B * KV, R, hd)
    s = jax.lax.dot_general(q2, k2.astype(q2.dtype),
                            (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask3 = jnp.broadcast_to(mask_rows, (B, mask_rows.shape[1], L))
    s = s + jnp.repeat(mask3, KV, axis=0)             # (B*KV, R|1, L)
    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(m > NEG_INF / 2, m, 0.0)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
    acc = jax.lax.dot_general(p.astype(v2.dtype), v2,
                              (((2,), (1,)), ((0,), (0,))),
                              preferred_element_type=jnp.float32)
    out = acc / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return out.reshape(B, KV, R, hd)


def paged_attention_ref(q, k_pool, v_pool, table, mask, *, softcap=0.0):
    """Oracle for kernels.paged_attention.paged_attention_pallas.

    One-shot masked softmax over the table-gathered view — mathematically
    identical to the kernel's online-softmax chunk walk (parity is ulp-level:
    accumulation order differs), with the kernel's masking semantics: a row
    with no visible lane yields exact zeros, fully-masked lanes contribute
    exact zeros.  q (B, KV, G, hd); pools (NB+1, bs, KV, hd); table (B, T)
    int32; mask (B, T*bs) additive fp32.  Returns (B, KV, G, hd) fp32.

    This rung is also the production decode path on CPU hosts (ops.py "auto"
    dispatch), so it is written for speed there: one fused gather of the
    *length-clamped* view (the serving engine clamps `table`/`mask` to the
    live block-rounded bucket, not max_len) + one batched-GEMM attend
    (_bmm_masked_attend).  The never-materialize-the-view property belongs
    to the pallas rung, where the view would otherwise round-trip through
    HBM per layer per step.
    """
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    T = table.shape[1]
    L = T * bs
    kv = k_pool[table].reshape(B, L, KV, hd)           # (B, T, bs, ...) flat
    vv = v_pool[table].reshape(B, L, KV, hd)
    return _bmm_masked_attend(q, kv, vv, mask[:, None, :], softcap=softcap)


def paged_attention_decode_ref(q, k_pool, v_pool, table, mask, k_new, v_new,
                               wblk, woff, wok, *, softcap=0.0):
    """Oracle for kernels.paged_attention.paged_attention_decode_pallas.

    Scatter-then-attend with the exact semantics of the legacy two-op decode
    path (`attention._paged_write` + gather + attend): row b writes
    k_new/v_new (B, KV, hd) at pool[wblk[b], woff[b]] iff wok[b], rows with
    wok[b] == 0 are redirected out of bounds and dropped — so the returned
    pools are *bit-identical* to the scatter path (same values, same dtype
    cast), which the fused-write property harness enforces.  Also the CPU
    production rung for one-launch decode (ops.py "auto").
    """
    blk = jnp.where(wok != 0, wblk, k_pool.shape[0])          # OOB: dropped
    k_pool = k_pool.at[blk, woff].set(k_new.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[blk, woff].set(v_new.astype(v_pool.dtype), mode="drop")
    out = paged_attention_ref(q, k_pool, v_pool, table, mask, softcap=softcap)
    return out, k_pool, v_pool


def paged_prefill_ref(q, k_pool, v_pool, table, qpos, *, softcap=0.0):
    """Oracle for kernels.paged_prefill.paged_prefill_pallas.

    One-shot masked softmax over the gathered view with the causal mask
    derived from `qpos` exactly as the kernel derives it in-register: kv
    position p visible to query row r iff p <= qpos[b, r].  q (B, KV, R, hd)
    with R = chunk_lanes * G; qpos (B, R) int32.  Returns (B, KV, R, hd)
    fp32.  Also the CPU production rung for kernel-dispatched chunked
    prefill.
    """
    B, KV, R, hd = q.shape
    bs = k_pool.shape[1]
    L = table.shape[1] * bs
    kv = k_pool[table].reshape(B, L, KV, hd)
    vv = v_pool[table].reshape(B, L, KV, hd)
    mask_rows = jnp.where(
        jnp.arange(L)[None, None, :] <= qpos[:, :, None], 0.0,
        NEG_INF).astype(jnp.float32)                   # (B, R, L)
    return _bmm_masked_attend(q, kv, vv, mask_rows, softcap=softcap)


def emt_bitserial_ref(xq, w, rho, *, device: DeviceModel, bits=7, seed=0,
                      base_plane=0):
    """Oracle for kernels.emt_bitserial.emt_bitserial_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    sign = jnp.sign(xq.astype(jnp.float32))
    mag = jnp.abs(xq.astype(jnp.float32))
    acc = jnp.zeros((*xq.shape[:-1], n), jnp.float32)
    for p in range(bits):
        offs = hashrng.tile_state_offsets(
            seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs,
            plane=base_plane + p)
        wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
        planes = (sign * bit_plane(mag, p)).astype(w.dtype)
        acc = acc + (2.0 ** p) * jnp.matmul(
            planes, wn, preferred_element_type=jnp.float32).astype(jnp.float32)
    return acc
