"""Pure-jnp oracles for the Pallas kernels — bit-exact by construction.

The kernels sample RTN states from global element coordinates through
:mod:`repro.core.hashrng`; these references do the same over the un-tiled arrays, so
(kernel, reference) pairs agree to fp32 accumulation order.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashrng
from repro.core.device import DeviceModel
from repro.core.decompose import bit_plane


def emt_matmul_ref(x, w, rho, *, device: DeviceModel, seed=0, plane=0):
    """Oracle for kernels.emt_matmul.emt_matmul_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    offs = hashrng.tile_state_offsets(
        seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs, plane=plane)
    wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
    return jnp.matmul(x, wn, preferred_element_type=jnp.float32).astype(jnp.float32)


def emt_bitserial_ref(xq, w, rho, *, device: DeviceModel, bits=7, seed=0,
                      base_plane=0):
    """Oracle for kernels.emt_bitserial.emt_bitserial_pallas."""
    kdim, n = w.shape
    sig = device.sigma_rel(jnp.asarray(rho, jnp.float32))
    sign = jnp.sign(xq.astype(jnp.float32))
    mag = jnp.abs(xq.astype(jnp.float32))
    acc = jnp.zeros((*xq.shape[:-1], n), jnp.float32)
    for p in range(bits):
        offs = hashrng.tile_state_offsets(
            seed, 0, 0, (kdim, n), device.state_offsets, device.state_probs,
            plane=base_plane + p)
        wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
        planes = (sign * bit_plane(mag, p)).astype(w.dtype)
        acc = acc + (2.0 ** p) * jnp.matmul(
            planes, wn, preferred_element_type=jnp.float32).astype(jnp.float32)
    return acc
