"""Pallas TPU kernel — bit-serial decomposed noisy matmul (technique C).

Analog semantics (Fig. 8(b)): the crossbar is read once per activation bit-plane;
every read draws an independent RTN state; plane outputs are accumulated at 2^p.

TPU mapping:
* One kernel invocation per (bm, bn, bk) tile; the **bit loop is innermost and
  unrolled inside the kernel**, so the weight tile is loaded from HBM→VMEM *once*
  and re-read (with fresh in-register noise) `bits` times — the MXU analogue of
  "read the same cell eight times", costing 8x MXU issue but 1x HBM weight traffic.
* Bit-planes are extracted on VREGs from the integer activation levels — the
  (bits, M, K) plane tensor never exists in HBM either.
* Accumulation is fp32 in VMEM across both K-steps and bit-planes.

Inputs are *integer-valued float levels* (from repro.core.quant.quant_levels); sign
is applied to the plane (signed bits in {-1, 0, +1}), matching ref.py exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashrng
from repro.core.device import DeviceModel


def _kernel(x_ref, w_ref, rho_ref, o_ref, *, bk, bits, seed, base_plane, device):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = pl.program_id(2)
    j = pl.program_id(1)
    xq = x_ref[...].astype(jnp.float32)      # integer-valued levels
    w = w_ref[...]
    rho = rho_ref[0]
    sig = device.sigma_rel(rho).astype(jnp.float32)

    sign = jnp.sign(xq)
    mag = jnp.abs(xq)
    row0 = k * bk
    col0 = j * w.shape[1]

    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for p in range(bits):                    # unrolled bit loop — w tile reused
        offs = hashrng.tile_state_offsets(
            seed, row0, col0, w.shape, device.state_offsets, device.state_probs,
            plane=base_plane + p)
        wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
        plane_bits = (sign * (jnp.floor(mag / (2.0 ** p)) % 2.0)).astype(w.dtype)
        acc += (2.0 ** p) * jnp.dot(plane_bits, wn,
                                    preferred_element_type=jnp.float32)
    o_ref[...] += acc


def emt_bitserial_pallas(xq, w, rho, *, device: DeviceModel, bits=7, seed=0,
                         base_plane=0, bm=128, bn=128, bk=128, interpret=False):
    """xq: (M, K) integer-valued float levels; w: (K, N) -> (M, N) float32."""
    m, kdim = xq.shape
    k2, n = w.shape
    assert kdim == k2
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        f"shapes {(m, kdim, n)} must tile by {(bm, bk, bn)}"
    grid = (m // bm, n // bn, kdim // bk)
    rho_arr = jnp.asarray(rho, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, bits=bits, seed=seed,
                          base_plane=base_plane, device=device),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xq, w, rho_arr)
