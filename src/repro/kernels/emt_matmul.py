"""Pallas TPU kernel — fused noisy-crossbar matmul (technique A forward).

Computes  y = x @ (w_q * (1 + a_l(state) * sigma_rel(rho)))  with the RTN state
sampled *inside the kernel* from the counter-hash RNG: noise never exists in HBM.

TPU mapping (DESIGN.md §3):
* grid = (M/bm, N/bn, K/bk); the K dimension is innermost so the fp32 accumulator
  tile stays resident in VMEM across K steps (revisiting semantics of out_specs).
* Block shapes are multiples of 128 to line up with MXU tiles / VREG lanes.
* The hash RNG is evaluated on the (bk, bn) weight tile from its *global* element
  coordinates, so the result is bit-identical to the jnp reference (ref.py) and
  invariant to the chosen block decomposition and to SPMD sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashrng
from repro.core.device import DeviceModel


def _kernel(x_ref, w_ref, rho_ref, o_ref, *, bk, seed, plane, device, k0_base):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = pl.program_id(2)
    j = pl.program_id(1)
    x = x_ref[...]
    w = w_ref[...]
    rho = rho_ref[0]
    sig = device.sigma_rel(rho).astype(jnp.float32)
    # global coordinates of this weight tile
    row0 = k0_base + k * bk
    col0 = j * w.shape[1]
    offs = hashrng.tile_state_offsets(
        seed, row0, col0, w.shape, device.state_offsets, device.state_probs,
        plane=plane)
    wn = (w.astype(jnp.float32) * (1.0 + offs * sig)).astype(w.dtype)
    o_ref[...] += jnp.dot(x, wn, preferred_element_type=jnp.float32)


def emt_matmul_pallas(x, w, rho, *, device: DeviceModel, seed=0, plane=0,
                      bm=128, bn=128, bk=128, interpret=False):
    """x: (M, K) float; w: (K, N); rho: scalar -> (M, N) float32."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        f"shapes {(m, kdim, n)} must tile by {(bm, bk, bn)}"
    grid = (m // bm, n // bn, kdim // bk)
    rho_arr = jnp.asarray(rho, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, seed=seed, plane=plane, device=device,
                          k0_base=0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, rho_arr)
