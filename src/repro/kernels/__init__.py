"""Pallas TPU kernels for the EMT compute hot-spots.

The paper's core computation — noisy analog crossbar MACs, plain (technique A) and
bit-serial decomposed (technique C) — is the performance-critical inner loop of every
EMT model. `emt_matmul.py` / `emt_bitserial.py` hold the `pl.pallas_call` kernels with
explicit BlockSpec VMEM tiling, `paged_attention.py` the fused block-table
decode-attention kernel (vLLM style: the gather happens inside the kernel),
`ops.py` the jit'd wrappers, `ref.py` the pure-jnp oracles (bit-exact via the
shared counter-hash RNG; chunk-order-exact for the attention kernel).

See docs/kernels.md for the dispatch ladder (pallas / interpret / ref) and
block-size tuning guidance.
"""
from repro.kernels.emt_matmul import emt_matmul_pallas
from repro.kernels.emt_bitserial import emt_bitserial_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels import ops, ref
