"""Pallas TPU kernels for the EMT compute hot-spots.

The paper's core computation — noisy analog crossbar MACs, plain (technique A) and
bit-serial decomposed (technique C) — is the performance-critical inner loop of every
EMT model. `emt_matmul.py` / `emt_bitserial.py` hold the `pl.pallas_call` kernels with
explicit BlockSpec VMEM tiling, `ops.py` the jit'd wrappers, `ref.py` the pure-jnp
oracles (bit-exact via the shared counter-hash RNG).
"""
from repro.kernels.emt_matmul import emt_matmul_pallas
from repro.kernels.emt_bitserial import emt_bitserial_pallas
from repro.kernels import ops, ref
