"""Pallas TPU kernel — flash-style chunked prefill over the paged KV cache.

The chunked-prefill path (`models/attention._chunk_attend`, non-ring layers)
used to write the chunk's K/V into the pool and then *gather the full
(B, view_len, KV, hd) logical view* to attend against — the same
materialize-then-attend waste the fused decode kernel killed for single-token
steps, paid once per layer per chunk.  This kernel attends the chunk's query
tile straight against table-resolved pool tiles with an online softmax: the
view never exists.

Shape story: the (B, C, H, hd) query chunk is regrouped to (B, KV, C * G, hd)
— the kv-head axis becomes a grid dimension and the C chunk lanes x G group
heads collapse into one query-tile row axis, so each grid step runs a single
(C * G, chunk_positions) score matmul (C and G are both small; fusing them
keeps the MXU fed).

Causality is derived *in-kernel* from the per-lane query positions instead of
a materialized (B, 1, C, L) mask: kv position p is visible to query row r iff
``p <= qpos[b, r // G]`` — this covers the causal prefix, in-chunk causality
(the chunk's own K/V is written to the pool before the kernel runs), the
clamped-view tail (positions past the view hold qpos < p), and padding lanes
(their qpos is clamped to the row's last real lane, exactly like the legacy
mask built by `lm.chunk_step`).

Speed levers (mirrors kernels/paged_attention.py — see its module docstring):
pools stay in HBM (ANY) with double-buffered ``make_async_copy`` tile DMA,
``block_chunk`` pool blocks stream per grid step, statistics scratch is
(8, 128)-aligned.  One extra lever decode doesn't have: per-row chunk
*skipping*.  A scalar-prefetched ``qlast[b] = max(qpos[b])`` bounds each
row's visible range, and chunks entirely past it are neither copied nor
attended (`@pl.when` on both the DMA start and the compute) — a row early in
its prompt touches only the blocks it can see, which is where the analytic
K/V byte win over the materialized view comes from.

Parity: kernels/ref.py::paged_prefill_ref is the one-shot-softmax oracle
(ulp-level agreement, accumulation order differs); masking semantics are
identical to the decode kernel (NEG_INF sentinel, m_safe guard, exact zeros
for fully-masked rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention import NEG_INF, _stats_rows


def _prefill_kernel(table_ref, qlast_ref, q_ref, qpos_ref, k_hbm, v_hbm,
                    o_ref, kbuf, vbuf, sem, m_ref, l_ref, acc_ref,
                    *, scale, softcap, cpb, bs, R):
    """One (batch row, kv head, kv block chunk) grid step; R = C * G query
    rows per tile."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    c = pl.program_id(2)
    C = pl.num_programs(2)
    span = cpb * bs                                   # kv positions per step

    def chunk_needed(ci):
        # chunks strictly past the row's furthest visible position are dead
        return ci * span <= qlast_ref[b]

    def start_chunk(ci, slot):
        for i in range(cpb):
            blk = table_ref[b, ci * cpb + i]
            pltpu.make_async_copy(k_hbm.at[blk, :, h, :], kbuf.at[slot, i],
                                  sem.at[slot, 0, i]).start()
            pltpu.make_async_copy(v_hbm.at[blk, :, h, :], vbuf.at[slot, i],
                                  sem.at[slot, 1, i]).start()

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        start_chunk(0, 0)

    @pl.when((c + 1 < C) & chunk_needed(c + 1))
    def _prefetch_next():                             # double buffer
        start_chunk(c + 1, (c + 1) % 2)

    @pl.when(chunk_needed(c))
    def _attend():
        slot = c % 2
        for i in range(cpb):
            pltpu.make_async_copy(k_hbm.at[0, :, h, :], kbuf.at[slot, i],
                                  sem.at[slot, 0, i]).wait()
            pltpu.make_async_copy(v_hbm.at[0, :, h, :], vbuf.at[slot, i],
                                  sem.at[slot, 1, i]).wait()
        k = kbuf[slot].reshape(span, -1)              # (span, hd)
        v = vbuf[slot].reshape(span, -1)
        q = q_ref[0, 0]                               # (R, hd)
        s = jax.lax.dot_general(q, k.astype(q.dtype), (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        # in-kernel causal mask: kv position vs per-row clamped query position
        qp = qpos_ref[0][:, None]                     # (R, 1)
        p = c * span + jax.lax.broadcasted_iota(jnp.int32, (R, span), 1)
        s = s + jnp.where(p <= qp, 0.0, NEG_INF)

        m_prev = m_ref[0:R]
        l_prev = l_ref[0:R]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        pr = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
        corr = jnp.exp(m_prev - m_safe)
        l_ref[0:R] = l_prev * corr + jnp.sum(pr, axis=-1, keepdims=True)
        acc_ref[0:R] = acc_ref[0:R] * corr + jax.lax.dot_general(
            pr.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0:R] = m_new

    @pl.when(c == C - 1)
    def _done():
        o_ref[...] = (acc_ref[0:R] /
                      jnp.maximum(l_ref[0:R], 1e-30))[None, None]


def paged_prefill_pallas(q, k_pool, v_pool, table, qpos, qlast, *,
                         softcap=0.0, block_chunk=1, interpret=False):
    """Chunked-prefill flash attention through the block table.

    q:      (B, KV, R, hd) query tile, R = chunk_lanes * G, row r = lane
            (r // G), group head (r % G) — post-RoPE, chunk K/V already
            written to the pools.
    k_pool/v_pool: (num_blocks + 1, block_size, KV, hd), zero block last.
    table:  (B, T) int32, T a multiple of ``block_chunk``.
    qpos:   (B, R) int32 — absolute query position per tile row (padding
            lanes clamped to the row's last real lane).
    qlast:  (B,) int32 — max over qpos rows (chunk-skip bound).

    Returns (B, KV, R, hd) fp32.
    """
    B, KV, R, hd = q.shape
    bs = k_pool.shape[1]
    T = table.shape[1]
    cpb = int(block_chunk)
    assert T % cpb == 0, (T, cpb)
    assert qpos.shape == (B, R), (qpos.shape, (B, R))
    assert k_pool.shape == v_pool.shape and k_pool.shape[2] == KV
    C = T // cpb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, C),
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, h, c, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, R), lambda b, h, c, *_: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, R, hd), lambda b, h, c, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, cpb, bs, hd), k_pool.dtype),
            pltpu.VMEM((2, cpb, bs, hd), v_pool.dtype),
            pltpu.SemaphoreType.DMA((2, 2, cpb)),
            pltpu.VMEM((_stats_rows(R), 1), jnp.float32),
            pltpu.VMEM((_stats_rows(R), 1), jnp.float32),
            pltpu.VMEM((_stats_rows(R), hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _prefill_kernel, scale=1.0 / np.sqrt(hd),
        softcap=float(softcap or 0.0), cpb=cpb, bs=bs, R=R)
    # qpos rides as a VMEM tile (mask arithmetic), qlast as scalar prefetch
    # (control flow)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, R, hd), jnp.float32),
        interpret=interpret,
    )(table, qlast, q, qpos, k_pool, v_pool)
