"""Pallas TPU kernel — fused paged-attention decode (vLLM block-table style).

One query token per batch row attends a block-table-paged KV cache *without
ever materializing the (B, logical_len, KV, hd) gathered view* — and, in the
fused-write variant, the step's new K/V token is scattered through the block
table inside the same kernel launch, so decode is ONE kernel per layer: no
separate scatter op, no gather, no view.

Raw-speed layout (this file's second generation — the first pulled one
(block_size, hd) tile per grid step through BlockSpec index maps):

* K/V pools stay in HBM (``memory_space=ANY``); the kernel owns the tile
  movement with explicit ``make_async_copy`` DMAs instead of BlockSpec
  pipelining, because the pool tiles it needs are scattered by the block
  table and per-(block) granularity grid steps leave the MXU idle between
  tiny (block_size, hd) matmuls.
* grid = (B, KV, C) where each C step covers a *chunk* of ``block_chunk``
  blocks: one (block_chunk * block_size, hd) score matmul per step.
  ``kernels/ops.py::pick_block_chunk`` chooses the chunk from the clamped
  view width (occupancy) so small views run in one step and large views
  amortize the online-softmax recurrence.
* double-buffered DMA: chunk c+1's block tiles start copying while chunk c
  computes (2-slot VMEM scratch, per-slot DMA semaphores), hiding pool
  latency behind the attend.
* scratch is (8, 128)-lane aligned: the running (max, sum, acc) statistics
  are padded to 8 sublanes (G is usually < 8) and sliced back, so vector
  loads never straddle tile boundaries.
* the fused write lands the (hd,) K/V rows for the step's token at
  ``pool[table[b, wpos // bs], wpos % bs, h]`` *before* chunk 0's read DMA
  is issued — the token always sees its own write, matching the scatter-
  then-attend ordering of the fallback path bit-for-bit.

Aliasing invariant (``input_output_aliases`` pins the output pools to the
input pool buffers): every pool element is either overwritten with the new
token's row (at most one (b) row per launch, gated by ``wok``) or left
untouched in place — the kernel never reads-modifies-writes pool content, so
retired blocks keep their engine-zeroed state and prefix-shared blocks are
only ever written through refcount-1 tables (the engine appends into
exclusively-owned tail blocks; see serve/engine.py).

The accumulation is the same online-softmax recurrence the chunked prefill
path in :func:`repro.models.attention._gqa_core` uses: running (max, sum,
acc) statistics with `softcap` applied before the additive mask and
`NEG_INF` masked lanes contributing exact zeros, so fully-masked chunks
(zero-block reads for unallocated table entries, ring positions not yet
written) cannot pollute the normalizer.

Bit-exactness note: the fp32 accumulation *order* differs from the one-shot
softmax the gather fallback and the jnp reference
(kernels/ref.py::paged_attention_ref) use, so outputs agree to fp32 rounding
(~1e-7 relative), which preserves temperature-0 token identity — the
property the serving harness (tests/test_paged_attention.py) enforces.  Pool
contents after the fused write are bit-identical to the scatter path: the
written rows are the same values cast to the same dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The additive-mask sentinel. Single definition for the kernel stack (ops.py,
# ref.py and paged_prefill.py import it); MUST equal models.common.NEG_INF,
# which builds the mask rows this kernel thresholds against (kernels cannot
# import models — layering — so the tie is enforced by
# tests/test_paged_attention.py).
NEG_INF = -1e30

# sublane padding for the (G, ·) statistics scratch — fp32 VMEM tiles are
# (8, 128); G (query heads per kv head) is typically 1..8
_SUBLANE = 8


def _stats_rows(g: int) -> int:
    return max(_SUBLANE, -(-g // _SUBLANE) * _SUBLANE)


def _decode_kernel(table_ref, wblk_ref, woff_ref, wok_ref,
                   q_ref, knew_ref, vnew_ref, mask_ref, k_hbm, v_hbm,
                   o_ref, kout_hbm, vout_hbm,
                   kbuf, vbuf, sem, wsem, m_ref, l_ref, acc_ref,
                   *, scale, softcap, cpb, bs, G, has_write):
    """One (batch row, kv head, block chunk) grid step.

    ``cpb`` blocks stream per step; ``kout_hbm``/``vout_hbm`` alias the input
    pools, and all reads go through the *output* refs so the fused write (at
    chunk 0) is ordered before every chunk read of the same launch.
    """
    b = pl.program_id(0)
    h = pl.program_id(1)
    c = pl.program_id(2)
    C = pl.num_programs(2)

    def start_chunk(ci, slot):
        for i in range(cpb):
            blk = table_ref[b, ci * cpb + i]
            pltpu.make_async_copy(kout_hbm.at[blk, :, h, :], kbuf.at[slot, i],
                                  sem.at[slot, 0, i]).start()
            pltpu.make_async_copy(vout_hbm.at[blk, :, h, :], vbuf.at[slot, i],
                                  sem.at[slot, 1, i]).start()

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if has_write:
            # the new token's (hd,) K/V rows land before any read DMA is
            # issued — the token always sees its own write, like the
            # scatter-then-attend fallback
            @pl.when(wok_ref[b] != 0)
            def _write():
                kw = pltpu.make_async_copy(
                    knew_ref.at[0, 0], kout_hbm.at[wblk_ref[b], woff_ref[b], h],
                    wsem.at[0])
                vw = pltpu.make_async_copy(
                    vnew_ref.at[0, 0], vout_hbm.at[wblk_ref[b], woff_ref[b], h],
                    wsem.at[1])
                kw.start()
                vw.start()
                kw.wait()
                vw.wait()
        start_chunk(0, 0)

    @pl.when(c + 1 < C)
    def _prefetch_next():                       # double buffer: overlap DMA
        start_chunk(c + 1, (c + 1) % 2)

    slot = c % 2
    for i in range(cpb):
        pltpu.make_async_copy(kout_hbm.at[0, :, h, :], kbuf.at[slot, i],
                              sem.at[slot, 0, i]).wait()
        pltpu.make_async_copy(vout_hbm.at[0, :, h, :], vbuf.at[slot, i],
                              sem.at[slot, 1, i]).wait()

    k = kbuf[slot].reshape(cpb * bs, -1)                   # (chunk, hd)
    v = vbuf[slot].reshape(cpb * bs, -1)
    q = q_ref[0, 0]                                        # (G, hd)
    s = jax.lax.dot_general(q, k.astype(q.dtype), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:                                            # gemma2 logit cap
        s = softcap * jnp.tanh(s / softcap)
    s = s + mask_ref[0][None, :]                           # (G, chunk)

    m_prev = m_ref[0:G]                                    # (G, 1)
    l_prev = l_ref[0:G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # masked lanes must contribute exact zeros even when the whole chunk is
    # masked; m_safe keeps every exp argument away from sentinel-minus-
    # sentinel differences (exact in strict fp, NaN-prone under XLA's
    # reassociating fusions — see kernels/ref.py, which mirrors this)
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
    corr = jnp.exp(m_prev - m_safe)
    l_ref[0:G] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[0:G] = acc_ref[0:G] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[0:G] = m_new

    @pl.when(c == C - 1)
    def _done():
        o_ref[...] = (acc_ref[0:G] /
                      jnp.maximum(l_ref[0:G], 1e-30))[None, None]


def _call(q, k_pool, v_pool, table, mask, knew, vnew, wblk, woff, wok, *,
          softcap, block_chunk, has_write, interpret):
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    T = table.shape[1]
    cpb = int(block_chunk)
    assert T % cpb == 0, (T, cpb)
    assert mask.shape == (B, T * bs), (mask.shape, (B, T * bs))
    assert k_pool.shape == v_pool.shape and k_pool.shape[2] == KV
    C = T // cpb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, KV, C),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, h, c, *_: (b, h, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, h, c, *_: (b, h, 0)),
            pl.BlockSpec((1, cpb * bs), lambda b, h, c, *_: (b, c)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c, *_: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, cpb, bs, hd), k_pool.dtype),    # K tiles (2 slots)
            pltpu.VMEM((2, cpb, bs, hd), v_pool.dtype),    # V tiles
            pltpu.SemaphoreType.DMA((2, 2, cpb)),          # per-slot/tile sems
            pltpu.SemaphoreType.DMA((2,)),                 # write sems (K, V)
            pltpu.VMEM((_stats_rows(G), 1), jnp.float32),  # running max
            pltpu.VMEM((_stats_rows(G), 1), jnp.float32),  # running sum
            pltpu.VMEM((_stats_rows(G), hd), jnp.float32),  # out accumulator
        ],
    )
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / np.sqrt(hd),
        softcap=float(softcap or 0.0), cpb=cpb, bs=bs, G=G,
        has_write=has_write)
    out, k_pool, v_pool = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
                   jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)],
        # operand indices include the 4 scalar-prefetch refs: k_pool is
        # operand 8, v_pool 9; outputs 1 and 2 are the pools
        input_output_aliases={8: 1, 9: 2},
        interpret=interpret,
    )(table, wblk, woff, wok, q, knew, vnew, mask, k_pool, v_pool)
    return out, k_pool, v_pool


def paged_attention_pallas(q, k_pool, v_pool, table, mask, *, softcap=0.0,
                           block_chunk=1, interpret=False):
    """Read-only fused paged-attention decode (cross-attention, parity tests).

    q:      (B, KV, G, hd) — one post-RoPE query token per row, grouped by
            kv head (H = KV * G, head h = k * G + g, matching _gqa_core).
    k_pool: (num_blocks + 1, block_size, KV, hd) serving pool (zero block
            last; unallocated table entries must already point at it).
    v_pool: same shape as k_pool.
    table:  (B, T) int32 block ids, T a multiple of ``block_chunk`` (the
            wrapper pads with the zero block).
    mask:   (B, T * block_size) additive fp32 rows; positions beyond the
            per-row visible range must be NEG_INF.

    Returns (B, KV, G, hd) fp32.
    """
    B, KV, _, hd = q.shape
    zeros = jnp.zeros((B, KV, hd), k_pool.dtype)
    zi = jnp.zeros((B,), jnp.int32)
    out, _, _ = _call(q, k_pool, v_pool, table, mask, zeros, zeros,
                      zi, zi, zi, softcap=softcap, block_chunk=block_chunk,
                      has_write=False, interpret=interpret)
    return out


def paged_attention_decode_pallas(q, k_pool, v_pool, table, mask, k_new,
                                  v_new, wblk, woff, wok, *, softcap=0.0,
                                  block_chunk=1, interpret=False):
    """Fused write + attend: ONE launch per decode layer.

    On top of :func:`paged_attention_pallas`: k_new/v_new (B, KV, hd) are the
    step's new K/V rows (already cast to the pool dtype); row b writes them
    at ``pool[wblk[b], woff[b], :, :]`` iff ``wok[b] != 0`` (int32), before
    any read of the launch.  The mask must already make the written position
    visible.  Returns (out, k_pool, v_pool) — the pools are aliased in-place
    updates of the inputs.
    """
    return _call(q, k_pool, v_pool, table, mask, k_new, v_new,
                 wblk, woff, wok, softcap=softcap, block_chunk=block_chunk,
                 has_write=True, interpret=interpret)
