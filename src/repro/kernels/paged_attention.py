"""Pallas TPU kernel — fused paged-attention decode (vLLM block-table style).

One query token per batch row attends a block-table-paged KV cache *without
ever materializing the (B, logical_len, KV, hd) gathered view*: the grid walks
(batch, kv_head, block-chunk), and each step DMAs exactly one `(block_size,
head_dim)` K/V tile straight out of the pool, routed through the block table
inside the kernel (the table is a scalar-prefetch operand, so the
`table[b, chunk]` lookup happens in the BlockSpec index map — compute goes to
where the data lives, nothing is gathered up front).

The accumulation is the same online-softmax recurrence the chunked prefill
path in :func:`repro.models.attention._gqa_core` uses: running (max, sum, acc)
statistics with `softcap` applied before the additive mask and `NEG_INF`
masked lanes contributing exact zeros, so fully-masked chunks (zero-block
reads for unallocated table entries, ring positions not yet written) cannot
pollute the normalizer.

TPU mapping:
* grid = (B, KV, num_chunks); the chunk dimension is innermost so the
  per-(row, head) accumulator scratch stays resident in VMEM across chunks.
* K/V pools keep their serving layout (num_blocks + 1, block_size, KV, hd);
  index map (table[b, c], 0, h, 0) pulls one (block_size, hd) tile per step.
* The additive mask rides along as (B, num_chunks * block_size) fp32 rows —
  positions beyond the logical length are pre-masked to NEG_INF by the
  wrapper (kernels/ops.py), which also owns padding and impl dispatch.

Bit-exactness note: the fp32 accumulation *order* differs from the one-shot
softmax the gather fallback and the jnp reference
(kernels/ref.py::paged_attention_ref) use, so outputs agree to fp32 rounding
(~1e-7 relative), which preserves temperature-0 token identity — the
property the serving harness (tests/test_paged_attention.py) enforces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The additive-mask sentinel. Single definition for the kernel stack (ops.py
# and ref.py import it); MUST equal models.common.NEG_INF, which builds the
# mask rows this kernel thresholds against (kernels cannot import models —
# layering — so the tie is enforced by tests/test_paged_attention.py).
NEG_INF = -1e30


def _decode_kernel(table_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale, softcap):
    """One (batch row, kv head, block chunk) grid step."""
    c = pl.program_id(2)
    last = pl.num_programs(2) - 1

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                    # (G, hd)
    k = k_ref[0, :, 0, :]                              # (bs, hd)
    v = v_ref[0, :, 0, :]                              # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:                                        # gemma2-style logit cap
        s = softcap * jnp.tanh(s / softcap)
    s = s + mask_ref[0][None, :]                       # (G, bs) + (1, bs)

    m_prev = m_ref[...]                                # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # masked lanes must contribute exact zeros even when the whole chunk is
    # masked; m_safe keeps every exp argument away from sentinel-minus-
    # sentinel differences (exact in strict fp, NaN-prone under XLA's
    # reassociating fusions — see kernels/ref.py, which mirrors this)
    m_safe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_safe), 0.0)
    corr = jnp.exp(m_prev - m_safe)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(c == last)
    def _done():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30))[None, None]


def paged_attention_pallas(q, k_pool, v_pool, table, mask, *, softcap=0.0,
                           interpret=False):
    """Fused paged-attention decode.

    q:      (B, KV, G, hd) — one post-RoPE query token per row, grouped by
            kv head (H = KV * G, head h = k * G + g, matching _gqa_core).
    k_pool: (num_blocks + 1, block_size, KV, hd) serving pool (zero block
            last; unallocated table entries must already point at it).
    v_pool: same shape as k_pool.
    table:  (B, T) int32 block ids — the (possibly length-clamped) block
            table rows.
    mask:   (B, T * block_size) additive fp32 rows; logical positions beyond
            the per-row visible range (and any padding past the logical
            length) must be NEG_INF.

    Returns (B, KV, G, hd) fp32.
    """
    B, KV, G, hd = q.shape
    bs = k_pool.shape[1]
    T = table.shape[1]
    assert mask.shape == (B, T * bs), (mask.shape, (B, T * bs))
    assert k_pool.shape == v_pool.shape and k_pool.shape[2] == KV

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, T),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c, tab: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, c, tab: (tab[b, c], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, c, tab: (tab[b, c], 0, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, c, tab: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, c, tab: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running sum
            pltpu.VMEM((G, hd), jnp.float32),      # output accumulator
        ],
    )
    kernel = functools.partial(_decode_kernel, scale=1.0 / np.sqrt(hd),
                               softcap=float(softcap or 0.0))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(table, q, k_pool, v_pool, mask)
