"""Heterogeneous speculative decoding across EMT technology corners.

The EMT-native twist on speculative decoding (ROADMAP item 3): the *draft*
and *target* are the **same weights** on two different device placements of
one heterogeneous fabric.  A cheap deterministic `sram_digital` placement
(amplitude-0 reads — quantization still applies, so it is a faithful digital
execution of the same network) proposes ``k`` tokens per slot; the expensive
analog placement (PCM/RRAM) then scores all ``k`` proposals in **one**
mixed-step call — ``lm.chunk_step`` with ``all_lanes=True`` is exactly the
verify primitive, since chunked prefill already writes rows at exact
positions and returns per-lane logits.

Why this saves analog energy at all: the per-lane MAC/ADC energy of a
(k+1)-lane verify chunk is the same as k+1 single-lane decode steps — the
win comes from the **per-step static macro-activation cost**
(:meth:`~repro.core.device.DeviceModel.static_energy`, the array-to-system
gap of measured PCM silicon): one verify step biases each crossbar tile
*once* for k+1 token positions, where plain decode pays the static tax per
token.  Acceptance rate then decides whether the (k - L) rejected lanes'
dynamic energy eats the static savings — the bench sweeps this
(benchmarks/bench_speculative.py).

Acceptance rule (greedy/temperature-0 only): lane ``j`` of the verify chunk
``[last_token, d_1 .. d_k]`` yields the target's greedy continuation after
``.. d_j``; the longest prefix of drafts matching those continuations is
accepted and the first mismatching lane's greedy token is committed as the
correction (or, when all k match, lane k's token rides along as a bonus) —
so every committed token **is** the target's greedy token given its prefix,
and generation is token-identical to plain greedy decode on the target
placement (deterministic-noise property, tests/test_speculative.py).

Energy accounting: both placements bill into the **same** engine ledger
(total / idle / per-corner — the draft corner label just appears alongside
the analog ones), so the conservation invariant *per-request + idle ==
total* keeps holding across both engines' corners, for partials and
cancellations too.  The draft-side subset is additionally tracked per
request (``draft_energy_pj``) and per engine
(``draft_total_energy_pj``/``draft_idle_energy_pj``), giving the
draft/verify split without a second invariant.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import emt_for_corner
from repro.models import lm
from repro.serve.engine import (GenResult, ServingEngine, make_chunk_step,
                                make_serve_decode_step, make_verify_step)


class SpeculativeEngine(ServingEngine):
    """ServingEngine whose decode rounds draft ``spec_k`` tokens on a cheap
    digital placement and verify them in one all-lane chunk step on the
    analog target placement.

    The draft runs the *same parameters* (``draft_params`` defaults to the
    target's) against a contiguous shadow KV cache that mirrors every write
    the target makes: prefill lanes are mirrored lane-for-lane, committed
    tokens re-enter through the next round's draft decodes.

    Every analog round is the **same** (k+1)-lane verify chunk — a slot
    still streaming its prompt occupies its lanes with the next <= k+1
    prompt tokens (its last lane's argmax is the first generated token when
    the prompt completes) while its co-tenants keep speculating.  The spec
    engine therefore *never* runs the wide ``prefill_chunk`` mixed step:
    chunk energy is billed for all B x C lanes (padding included, the lanes
    physically flow through the crossbars), so folding admissions into the
    rounds that run anyway makes their marginal analog cost ~zero, where a
    fallback to the wide chunk paid B x prefill_chunk lanes per admission —
    ruinous under staggered retirements, which fragment a wave of arrivals
    into several admission rounds.

    Greedy only (``temperature == 0`` is enforced at validate()); chunked
    prefill is required (the verify step *is* a chunk step) and the prefix
    cache is not supported yet (the draft cache cannot share blocks, so a
    cache-skipped prefix would leave the draft blind).
    """

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 mesh=None, rules=None, draft_placement="sram_digital",
                 spec_k: int = 4, draft_params=None, **kw):
        super().__init__(cfg, params, batch_size, max_len, mesh=mesh,
                         rules=rules, **kw)
        if self.n_shards > 1:
            raise ValueError("speculative decoding is not sharded yet: the "
                             "draft shadow cache and verify step are single-"
                             "device (n_shards must be 1)")
        if not self.chunked:
            raise ValueError("speculative decoding requires chunked prefill "
                             "(the verify primitive is the chunk step)")
        if self.prefix_cache:
            raise ValueError("prefix_cache is not supported with speculative "
                             "decoding (the draft shadow cache cannot share "
                             "prefix blocks)")
        if self.cfg.sliding_window and "local" in self.cfg.blocks():
            # a rejected draft's write into a sliding-window *ring* buffer
            # wraps onto (and destroys) the oldest still-visible history —
            # position-indexed global K/V just gets harmlessly overwritten by
            # the next round's chunk, but a clobbered ring slot is never
            # rewritten.  Same restriction (and same reason) as prefix_cache.
            raise ValueError("speculative decoding requires an all-global "
                             "attention stack (rejected drafts would clobber "
                             "sliding-window ring K/V)")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_k = int(spec_k)
        if isinstance(draft_placement, str):
            draft_placement = emt_for_corner(draft_placement)
        self.draft_cfg = self.cfg.replace(emt=draft_placement)
        self.draft_params = params if draft_params is None else draft_params
        # contiguous shadow cache — the draft never pages (its placement is
        # dense digital; the paged machinery belongs to the target)
        self.draft_cache = lm.init_cache(self.draft_cfg, batch_size, max_len)
        self._draft_chunk = jax.jit(
            make_chunk_step(self.draft_cfg, mesh, rules), donate_argnums=(1,))
        self._draft_decode = jax.jit(
            make_serve_decode_step(self.draft_cfg, mesh, rules),
            donate_argnums=(1,))
        self._draft_zero = jax.jit(ServingEngine._zero_slot,
                                   donate_argnums=(0,))
        if self.paged:
            self._verify = jax.jit(
                make_verify_step(self.cfg, mesh, rules, self.page_lens),
                donate_argnums=(1,), static_argnames=("view_len",))
        else:
            self._verify = jax.jit(make_verify_step(self.cfg, mesh, rules),
                                   donate_argnums=(1,))
        # draft-side ledger (subset of the combined totals) + accept stats
        self.draft_total_energy_pj = 0.0
        self.draft_idle_energy_pj = 0.0
        self.draft_steps = 0
        self.spec_rounds = 0
        self.spec_proposed_total = 0
        self.spec_accepted_total = 0
        # accept_len_hist[L] = verify rounds that accepted exactly L drafts
        self.accept_len_hist = np.zeros(self.spec_k + 1, np.int64)
        # slots whose draft cache is one position behind: a fully-accepted
        # round commits the bonus token (verify lane k), whose *predecessor*
        # d_k the draft proposed but never decoded — so d_k's K/V at
        # position pos-1 is missing from the shadow cache and would never be
        # rewritten (the next round's writes start at pos).  Such slots get
        # a one-lane catch-up chunk write before their next draft.
        self._draft_lag: dict = {}

    # -- request surface -----------------------------------------------------
    def validate(self, req) -> np.ndarray:
        prompt = super().validate(req)
        if req.temperature != 0:
            raise ValueError("SpeculativeEngine is greedy-only: the "
                             "acceptance rule compares argmaxes "
                             f"(got temperature={req.temperature})")
        return prompt

    @property
    def accept_rate(self) -> float:
        return self.spec_accepted_total / max(1, self.spec_proposed_total)

    # -- metrics -------------------------------------------------------------
    def reset_metrics(self):
        super().reset_metrics()
        self.draft_total_energy_pj = 0.0
        self.draft_idle_energy_pj = 0.0
        self.draft_steps = 0
        self.spec_rounds = 0
        self.spec_proposed_total = 0
        self.spec_accepted_total = 0
        self.accept_len_hist[:] = 0

    def metrics(self) -> dict:
        m = super().metrics()
        m.update(
            draft_total_energy_pj=float(self.draft_total_energy_pj),
            draft_idle_energy_pj=float(self.draft_idle_energy_pj),
            draft_steps=int(self.draft_steps),
            spec_rounds=int(self.spec_rounds),
            spec_proposed_total=int(self.spec_proposed_total),
            spec_accepted_total=int(self.spec_accepted_total),
            accept_rate=float(self.accept_rate),
            accept_len_hist=[int(v) for v in self.accept_len_hist],
        )
        return m

    # -- draft-side bookkeeping ----------------------------------------------
    def _book_draft_step(self, eaux, rows, prefill_rows=frozenset()) -> float:
        """Book one draft-placement step into the combined ledger (so the
        engine-wide conservation invariant spans both placements) and into
        the draft-side split counters.  `rows` are the participating slot
        ids; the idle rows' share accrues to idle_energy_pj exactly like a
        target step."""
        self._steps += 1
        self.draft_steps += 1
        kv = float(eaux["kv_reads"])
        self.kv_reads_total += kv
        self.shard_kv_reads[0] += kv
        e = float(eaux["energy_pj"])
        self._book_corners(eaux["corners"])
        self.total_energy_pj += e
        self.shard_energy_pj[0] += e
        self.draft_total_energy_pj += e
        self.shard_occupancy[0] += len(rows)
        share = e / self.batch_size
        idle = share * (self.batch_size - len(rows))
        self.idle_energy_pj += idle
        self.shard_idle_energy_pj[0] += idle
        self.draft_idle_energy_pj += idle
        for i in rows:
            s = self.scheduler.slots[i]
            s.draft_energy_pj += share
            if i in prefill_rows:
                s.prefill_energy_pj += share
            else:
                s.energy_pj += share
        return share

    # -- the speculative round -----------------------------------------------
    def _chunk_advance(self, active) -> List[GenResult]:
        # prefill lanes ride the verify chunk (see class docstring): the
        # wide mixed step is never run, decode co-tenants keep speculating
        # through admissions
        return self._decode_advance(active)

    def _decode_advance(self, active) -> List[GenResult]:
        """One unified draft-k / verify-one round.

        Per decode slot: up to ``k_eff`` draft tokens are proposed by
        sequential greedy decodes on the draft placement (``k_eff`` clamps k
        to the slot's remaining token and cache budget, so verify writes
        never overrun the admission-time block reservation), then the target
        runs one (k+1)-lane verify chunk ``[last_token, d_1 .. d_k_eff]`` at
        the slot's exact positions and the longest greedy-matching draft
        prefix plus one target token is committed.  Rejected lanes' K/V is
        overwritten before any later query can attend it (write ranges are
        contiguous from each round's start and a chunk's queries never look
        past its own write frontier).

        A slot still streaming its prompt instead fills its lanes with the
        next <= k+1 prompt tokens (no drafts, no acceptance bookkeeping);
        the round that reaches the prompt's end commits the last lane's
        argmax as the first generated token, exactly like the wide chunk
        step's final-chunk sampling at temperature 0."""
        k = self.spec_k
        B, C = self.batch_size, self.spec_k + 1
        keff = {}
        prefill_take = {}
        for i, s in active:
            if s.prefilling:
                prefill_take[i] = min(C, len(s.prompt) - s.pos)
                keff[i] = 0
            else:
                total = min(len(s.prompt) + s.req.max_new - 1, self.max_len)
                remaining = s.req.max_new - len(s.generated)
                keff[i] = max(0, min(k, remaining - 1, total - 1 - s.pos))

        # ---- draft mirror: prefill lanes (write-for-write lockstep with
        # the target) and catch-up lanes for draft-cache holes left by
        # fully-accepted rounds (see _draft_lag), in one chunk call
        lag_rows = [i for i, s in active
                    if not s.prefilling and self._draft_lag.pop(i, False)]
        mirror_rows = sorted(set(prefill_take) | set(lag_rows))
        if mirror_rows:
            tokm = np.zeros((B, C), np.int32)
            posm = np.zeros(B, np.int32)
            ntokm = np.ones(B, np.int32)
            actm = np.zeros(B, bool)
            for i in mirror_rows:
                s = self.scheduler.slots[i]
                actm[i] = True
                if i in prefill_take:
                    take = prefill_take[i]
                    tokm[i, :take] = s.prompt[s.pos:s.pos + take]
                    posm[i] = s.pos
                    ntokm[i] = take
                else:
                    tokm[i, 0] = s.generated[-2]
                    posm[i] = s.pos - 1
            zerosm = np.zeros(B, np.int32)
            step_seed = self.seed + self._steps + 1 if self.fresh_noise \
                else self.seed
            _, self.draft_cache, eaux = self._draft_chunk(
                self.draft_params, self.draft_cache, jnp.asarray(tokm),
                jnp.asarray(posm), jnp.asarray(ntokm),
                jnp.asarray(actm), jnp.uint32(step_seed),
                jnp.asarray(zerosm.astype(np.uint32)), jnp.asarray(zerosm),
                jnp.zeros(B, jnp.float32), jnp.asarray(zerosm),
                jnp.ones(B, jnp.float32))
            self._book_draft_step(eaux, mirror_rows,
                                  frozenset(prefill_take))

        # ---- draft phase: sequential greedy proposals on the cheap corner
        drafts = {i: [] for i, _ in active}
        cur_tok = np.zeros(B, np.int32)
        cur_pos = np.zeros(B, np.int32)
        for i, s in active:
            cur_tok[i] = s.last_token
            cur_pos[i] = s.pos
        zeros_i = np.zeros(B, np.int32)
        for j in range(max(keff.values(), default=0)):
            rows = [i for i, _ in active if keff[i] > j]
            if not rows:
                break
            act = np.zeros(B, bool)
            act[rows] = True
            step_seed = self.seed + self._steps + 1 if self.fresh_noise \
                else self.seed
            next_tok, self.draft_cache, eaux = self._draft_decode(
                self.draft_params, self.draft_cache, jnp.asarray(cur_tok),
                jnp.asarray(cur_pos), jnp.asarray(act),
                jnp.uint32(step_seed), jnp.asarray(zeros_i.astype(np.uint32)),
                jnp.asarray(zeros_i), jnp.zeros(B, jnp.float32),
                jnp.asarray(zeros_i), jnp.ones(B, jnp.float32),
                jnp.asarray(zeros_i))
            self._book_draft_step(eaux, rows)
            next_tok = np.asarray(next_tok)
            for i in rows:
                t = int(next_tok[i])
                drafts[i].append(t)
                cur_tok[i] = t
                cur_pos[i] += 1

        # ---- verify phase: one all-lane chunk step on the analog target
        tokens = np.zeros((B, C), np.int32)
        start = np.zeros(B, np.int32)
        ntok = np.ones(B, np.int32)
        act = np.zeros(B, bool)
        for i, s in active:
            act[i] = True
            start[i] = s.pos
            if i in prefill_take:
                take = prefill_take[i]
                tokens[i, :take] = s.prompt[s.pos:s.pos + take]
                ntok[i] = take
            else:
                row = [s.last_token] + drafts[i]
                tokens[i, :len(row)] = row
                ntok[i] = len(row)
        self.peak_concurrent = max(self.peak_concurrent, len(active))
        extra, kwargs = (), {}
        if self.paged:
            # prefill lanes write inside the admission-time prompt
            # allocation; only decode lanes can cross into reserved blocks
            for i, s in active:
                if i in prefill_take:
                    continue
                for p in range(s.pos, s.pos + int(ntok[i])):
                    if self.scheduler.kv_ensure(i, p):
                        self._tables_dev = None
            extra, kwargs = self._paged_tables(
                [int(max(start[i] + ntok[i] for i, _ in active))])
        step_seed = self.seed + self._steps + 1 if self.fresh_noise \
            else self.seed
        greedy, self.cache, eaux = self._verify(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(ntok), jnp.asarray(act), jnp.uint32(step_seed),
            *extra, **kwargs)
        share = float(self._book_step(eaux, active)[0])
        greedy = np.asarray(greedy)              # (B, C) per-lane target argmax

        # ---- host-side acceptance + commit
        finished = []
        for i, s in active:
            if i in prefill_take:
                take = prefill_take[i]
                s.prefill_energy_pj += share
                s.pos += take
                self.prefill_tokens_total += take
                if not s.prefilling:    # prompt done: last lane's argmax is
                    t = int(greedy[i, take - 1])    # the first greedy token
                    s.last_token = t
                    s.generated.append(t)
                    self._emit(s.rid, t)
                done = self._maybe_retire(i)
                if done is not None:
                    finished.append(done)
                continue
            s.energy_pj += share
            s.steps += 1
            m = keff[i]
            L = 0
            while L < m and drafts[i][L] == int(greedy[i, L]):
                L += 1
            # accepted drafts + the target's token for the first mismatching
            # lane (a correction when L < m, a free bonus token when L == m)
            commit = drafts[i][:L] + [int(greedy[i, L])]
            s.spec_proposed += m
            s.spec_accepted += L
            self.spec_proposed_total += m
            self.spec_accepted_total += L
            self.accept_len_hist[L] += 1
            self.spec_rounds += 1
            committed = 0
            for t in commit:
                s.pos += 1
                s.last_token = t
                s.generated.append(t)
                self._emit(s.rid, t)
                committed += 1
                if s.req.eos_id is not None and t == s.req.eos_id:
                    break
            if L == m and m > 0 and committed == len(commit):
                # full accept: the bonus token's predecessor d_m was never
                # draft-decoded, so its K/V is missing at pos-1 — schedule
                # the catch-up write for this slot's next draft round
                self._draft_lag[i] = True
            done = self._maybe_retire(i)
            if done is not None:
                finished.append(done)
        return finished

    # -- retirement hygiene --------------------------------------------------
    def _retire(self, slot_id: int, reason: str) -> GenResult:
        # the shadow cache gets the same zero-on-retire hygiene as the
        # target: a backfilled slot must never attend the previous
        # request's draft K/V (including rejected-draft residue)
        self._draft_lag.pop(slot_id, None)
        self.draft_cache = self._draft_zero(self.draft_cache,
                                            jnp.int32(slot_id))
        return super()._retire(slot_id, reason)
