from repro.serve.engine import (ServingEngine, GenRequest, make_prefill_step,
                                make_decode_step, serve_shardings)
