from repro.serve.engine import (ServingEngine, GenRequest, GenResult,
                                make_prefill_step, make_decode_step,
                                make_serve_decode_step, make_paged_decode_step,
                                make_sharded_chunk_step,
                                make_sharded_decode_step,
                                serve_shardings, prefill_bucket, view_bucket)
from repro.serve.kv_pool import BlockPool, PagedKV
from repro.serve.scheduler import RejectedError, Scheduler, Slot
from repro.serve.sampling import sample_tokens
from repro.serve.server import RequestHandle, StreamingServer
from repro.serve.spec import MatrixSpec, ScenarioSpec, ServeSpec
