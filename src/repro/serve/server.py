"""Async streaming front-end over the continuous-batching engine.

:class:`StreamingServer` turns the synchronous ``submit + step`` loop into a
request/response server: callers submit from any thread and get back a
:class:`RequestHandle` that **streams tokens as they are sampled** (not at
retire), while one driver thread owns the :class:`~repro.serve.engine.
ServingEngine` and feeds ``step()`` from a bounded admission queue.

Design
------
* **single driver loop** — the engine (jitted steps, scheduler, block pool)
  is not thread-safe, so every engine call happens on one thread: drain
  cancellations, expire deadlines, pump the admission queue into the engine
  FIFO, then ``engine.step()``.  Callers never touch the engine directly;
  ``submit()`` only runs the read-only :meth:`ServingEngine.validate` (static
  state) before handing the request across.  The same structure drops into an
  asyncio event loop (the driver loop is the executor job; handle queues map
  to per-request ``asyncio.Queue``) — threads keep the load-generator
  benchmark honest about wall-clock arrivals.
* **streaming** — the engine's ``on_token(rid, token)`` hook fires inside
  ``step()``/``_chunk_advance`` the moment a slot's token is sampled; the
  server stamps it with a monotonic timestamp and pushes it on the handle's
  event queue.  First tokens therefore reach the client while co-tenant
  requests are still decoding — TTFT and inter-token latency are measurable
  per request (:attr:`RequestHandle.ttft_s`, :attr:`RequestHandle.itl_s`).
* **cancellation / deadline timeout** — ``handle.cancel()`` (or an expired
  ``deadline_s``) retires the request wherever it is: still in the admission
  queue (empty result), in the engine FIFO, or mid-prefill/mid-decode in a
  slot.  The engine's :meth:`~repro.serve.engine.ServingEngine.cancel` frees
  the slot's paged blocks through the normal refcount/zero-on-retire hygiene
  and the partial result keeps the energy already billed, so per-request +
  idle == total conservation holds.  ``done_reason`` is ``"cancelled"`` /
  ``"timeout"``.
* **backpressure** — the admission queue is bounded (``max_pending``);
  ``submit()`` raises :class:`~repro.serve.scheduler.RejectedError` instead
  of queuing unservable work when it is full.  The driver moves requests into
  the engine FIFO only while the engine's own pending queue is shorter than
  the batch, so the block pool gates admission exactly as in synchronous
  serving and the end-to-end queue stays bounded.

Usage::

    with StreamingServer(engine, max_pending=16) as srv:
        h = srv.submit(GenRequest(prompt=..., max_new=32), deadline_s=2.0)
        for tok in h.tokens():        # yields as sampled
            ...
        res = h.result()              # GenResult incl. done_reason
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Iterator, List, Optional

import numpy as np

from repro.serve.engine import GenRequest, GenResult, ServingEngine
from repro.serve.scheduler import RejectedError

__all__ = ["StreamingServer", "RequestHandle", "RejectedError"]


class RequestHandle:
    """Caller-side view of one in-flight request: a stream of sampled tokens
    plus the final :class:`GenResult`.  Created by
    :meth:`StreamingServer.submit`; all fields are filled by the driver
    thread, all waiting happens on thread-safe queues/events."""

    def __init__(self, req: GenRequest, deadline_s: Optional[float]):
        self.req = req
        self.deadline_s = deadline_s
        self.t_submit = time.monotonic()   # arrival (queueing counts into TTFT)
        self.rid: Optional[int] = None     # engine rid once past the queue
        self.token_times: List[float] = [] # monotonic stamp per sampled token
        self._events: "queue.Queue" = queue.Queue()
        self._done = threading.Event()
        self._result: Optional[GenResult] = None
        self._cancel_reason: Optional[str] = None   # set by cancel()/deadline
        self._server: Optional["StreamingServer"] = None

    # -- caller API ----------------------------------------------------------
    def tokens(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Yield tokens as the engine samples them; returns at retirement.
        `timeout` bounds the wait for each next token (queue.Empty raised)."""
        while True:
            kind, payload = self._events.get(timeout=timeout)
            if kind == "done":
                return
            yield payload

    def next_token(self, timeout: Optional[float] = None) -> Optional[int]:
        """Next streamed token, or None once the request is finished."""
        kind, payload = self._events.get(timeout=timeout)
        return payload if kind == "token" else None

    def result(self, timeout: Optional[float] = None) -> GenResult:
        """Block until the request finishes; returns its GenResult (partial
        tokens + billed energy for cancelled/timed-out requests)."""
        if not self._done.wait(timeout):
            raise TimeoutError("request still in flight")
        return self._result

    def cancel(self) -> None:
        """Request cancellation (asynchronous: the driver retires the slot on
        its next loop iteration; await result() for the partial)."""
        if self._server is not None:
            self._server._request_cancel(self, "cancelled")

    @property
    def done(self) -> bool:
        return self._done.is_set()

    # -- latency metrics -----------------------------------------------------
    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (includes queueing), or None if none arrived."""
        if not self.token_times:
            return None
        return self.token_times[0] - self.t_submit

    @property
    def itl_s(self) -> List[float]:
        """Inter-token latencies (gaps between consecutive sampled tokens)."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    # -- driver side ---------------------------------------------------------
    def _push_token(self, token: int, t: float) -> None:
        self.token_times.append(t)
        self._events.put(("token", token))

    def _finish(self, result: GenResult) -> None:
        if self._done.is_set():
            return
        self._result = result
        self._events.put(("done", None))
        self._done.set()


class StreamingServer:
    """Bounded-admission streaming server over one :class:`ServingEngine`.

    The engine must be exclusively owned by this server while it runs (the
    driver thread is its only caller).  ``max_pending`` bounds the admission
    queue — the engine's own FIFO is additionally kept no longer than the
    batch, so at most ``max_pending + batch_size`` requests wait end-to-end.
    """

    def __init__(self, engine: ServingEngine, *, max_pending: int = 16,
                 poll_s: float = 0.001,
                 default_deadline_s: Optional[float] = None):
        self.engine = engine
        self.max_pending = int(max_pending)
        self.poll_s = float(poll_s)
        self.default_deadline_s = default_deadline_s
        self._lock = threading.Lock()          # guards _inbox + stats
        self._inbox: "deque[RequestHandle]" = deque()
        self._cancels: "deque[RequestHandle]" = deque()
        self._by_rid: dict = {}                # driver-thread only
        self._stopping = False
        self._drain_on_stop = True
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self.stats = {"submitted": 0, "rejected": 0, "completed": 0,
                      "cancelled": 0, "timeout": 0, "energy_budget": 0}
        engine.on_token = self._on_token

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "StreamingServer":
        assert self._thread is None, "server already started"
        self._thread = threading.Thread(target=self._run,
                                        name="serve-driver", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the driver.  ``drain=True`` finishes everything in flight
        first; ``drain=False`` cancels outstanding requests instead."""
        if self._thread is None:
            return
        self._drain_on_stop = drain
        self._stopping = True
        self._thread.join()
        self._thread = None
        if self.error is not None:
            raise RuntimeError("serve driver crashed") from self.error

    def __enter__(self) -> "StreamingServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)

    # -- caller API ----------------------------------------------------------
    def submit(self, req: GenRequest,
               deadline_s: Optional[float] = None) -> RequestHandle:
        """Enqueue `req`; returns its streaming handle.

        Raises ValueError for an invalid request (synchronously — the
        read-only engine validation runs on the calling thread) and
        :class:`RejectedError` when the bounded admission queue is full
        (backpressure: shed load or retry)."""
        self.engine.validate(req)
        handle = RequestHandle(
            req, self.default_deadline_s if deadline_s is None else deadline_s)
        handle._server = self
        with self._lock:
            if len(self._inbox) >= self.max_pending:
                self.stats["rejected"] += 1
                raise RejectedError(
                    f"admission queue full ({self.max_pending} pending)")
            self._inbox.append(handle)
            self.stats["submitted"] += 1
        return handle

    def _request_cancel(self, handle: RequestHandle, reason: str) -> None:
        with self._lock:
            if handle._cancel_reason is None and not handle.done:
                handle._cancel_reason = reason
                self._cancels.append(handle)

    # -- driver loop ---------------------------------------------------------
    def _run(self) -> None:
        eng = self.engine
        try:
            while True:
                self._do_cancels()
                self._expire_deadlines(time.monotonic())
                self._pump_inbox()
                if eng.scheduler.busy:
                    for res in eng.step():
                        self._finish_rid(res)
                elif self._stopping:
                    with self._lock:
                        empty = not self._inbox and not self._cancels
                    if empty or not self._drain_on_stop:
                        break
                else:
                    time.sleep(self.poll_s)
                if self._stopping and not self._drain_on_stop:
                    self._abort_outstanding()
                    break
        except BaseException as e:          # noqa: BLE001 — report, don't hang
            self.error = e
            try:
                self._abort_outstanding(reason="error")
            except BaseException:           # engine may be wedged: unblock
                for h in list(self._by_rid.values()):
                    h._finish(GenResult(
                        rid=h.rid if h.rid is not None else -1,
                        tokens=np.zeros(0, np.int32), energy_pj=0.0,
                        prefill_energy_pj=0.0, steps=0, done_reason="error"))
        finally:
            eng.on_token = None

    def _on_token(self, rid: int, token: int) -> None:
        h = self._by_rid.get(rid)
        if h is not None:
            h._push_token(token, time.monotonic())

    def _pump_inbox(self) -> None:
        """Move queued requests into the engine FIFO, at most batch_size deep
        — block-pool admission stays with the engine scheduler, and a caller
        rejection (bounded inbox) really means "the line is long"."""
        eng = self.engine
        while eng.scheduler.pending < eng.batch_size:
            with self._lock:
                if not self._inbox:
                    return
                h = self._inbox.popleft()
            if h.done:                       # cancelled/expired while queued
                continue
            h.rid = eng.submit(h.req)
            self._by_rid[h.rid] = h

    def _do_cancels(self) -> None:
        while True:
            with self._lock:
                if not self._cancels:
                    return
                h = self._cancels.popleft()
            self._cancel_now(h)

    def _cancel_now(self, h: RequestHandle) -> None:
        reason = h._cancel_reason or "cancelled"
        if h.done:
            return
        if h.rid is None:                    # never reached the engine
            res = GenResult(rid=-1, tokens=np.zeros(0, np.int32),
                            energy_pj=0.0, prefill_energy_pj=0.0, steps=0,
                            done_reason=reason)
        else:
            res = self.engine.cancel(h.rid, reason)
            if res is None:                  # raced a natural retirement
                return
        self._finish_rid(res, handle=h)

    def _expire_deadlines(self, now: float) -> None:
        with self._lock:
            live = list(self._by_rid.values()) + list(self._inbox)
        for h in live:
            if (h.deadline_s is not None and not h.done
                    and h._cancel_reason is None
                    and now - h.t_submit > h.deadline_s):
                h._cancel_reason = "timeout"
                self._cancel_now(h)

    def _finish_rid(self, res: GenResult,
                    handle: Optional[RequestHandle] = None) -> None:
        h = handle or self._by_rid.get(res.rid)
        if h is None:
            return                           # not server-submitted (warmup)
        if h.rid is not None:
            self._by_rid.pop(h.rid, None)
        key = res.done_reason if res.done_reason in (
            "cancelled", "timeout", "error", "energy_budget") else "completed"
        with self._lock:
            self.stats[key] = self.stats.get(key, 0) + 1
        h._finish(res)

    def _abort_outstanding(self, reason: str = "cancelled") -> None:
        for h in list(self._by_rid.values()):
            h._cancel_reason = h._cancel_reason or reason
            self._cancel_now(h)
        while True:
            with self._lock:
                if not self._inbox:
                    break
                h = self._inbox.popleft()
            h._cancel_reason = h._cancel_reason or reason
            self._cancel_now(h)
