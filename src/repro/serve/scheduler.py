"""FIFO slot scheduler for the continuous-batching engine (host-side, pure
Python — no jax in this module).

The engine owns a fixed batch of ``batch_size`` *slots*; each slot is either
free or bound to one in-flight request.  Requests enter a FIFO queue via
:meth:`Scheduler.submit`; the engine admits the queue head whenever a slot is
free (including mid-decode — backfill never recompiles the decode step because
the batch shape is static), and retires slots on EOS / ``max_new`` / cache
exhaustion.  The scheduler only does bookkeeping; prefill and decode stay in
the engine.

With a :class:`~repro.serve.kv_pool.PagedKV` attached, the scheduler also
maintains the per-request block tables: admission additionally requires the
free-block budget (prompt blocks + the decode worst-case reservation), decode
appends a block when a slot's position crosses a block boundary, and
retirement frees the request's blocks back to the pool.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

from repro.serve.kv_pool import PagedKV


class RejectedError(RuntimeError):
    """Admission refused under backpressure: the bounded pending queue is
    full.  Raised by :meth:`Scheduler.submit` (and surfaced through
    ``ServingEngine.submit`` / the streaming server) instead of letting the
    FIFO grow without bound while the block pool or batch is saturated — the
    caller sheds load or retries, the engine never queues unservable work."""


@dataclasses.dataclass
class Slot:
    """One in-flight request bound to a batch row."""
    rid: int                        # request id (submission order)
    req: object                     # the GenRequest
    pos: int                        # next cache write index (absolute)
    last_token: int                 # most recently sampled token (decode input)
    generated: List[int] = dataclasses.field(default_factory=list)
    energy_pj: float = 0.0          # decode-energy share accumulated so far
    prefill_energy_pj: float = 0.0
    steps: int = 0                  # decode steps this request participated in
    enc_len: int = 0                # real encoder positions cached (enc-dec)
    # speculative decoding (serve/speculative.py; all zero on plain engines):
    # the subset of energy_pj/prefill_energy_pj billed on the draft
    # placement, and the request's draft-token proposal/acceptance counters
    draft_energy_pj: float = 0.0
    spec_proposed: int = 0
    spec_accepted: int = 0
    # chunked prefill: the prompt still being streamed into the cache.  While
    # `pos < len(prompt)` the slot is in the prefill phase: each mixed step
    # consumes up to `prefill_chunk` prompt tokens at positions [pos, ...)
    # instead of decoding.  None = legacy one-shot bucketed prefill (the slot
    # is placed already decoded-ready).
    prompt: object = None           # np.ndarray prompt tokens, or None

    @property
    def prefilling(self) -> bool:
        return self.prompt is not None and self.pos < len(self.prompt)

    @property
    def sample_pos(self) -> int:
        """Request-relative sampling counter (0 = first/prefill token)."""
        return len(self.generated)


class Scheduler:
    """FIFO admission queue + slot table (+ optional paged-KV block tables).

    Data-parallel serving (``n_shards > 1``): the slot range is partitioned
    into ``n_shards`` contiguous groups of ``batch_size // n_shards`` slots —
    shard ``s`` owns slots ``[s*g, (s+1)*g)`` — matching the engine's
    batch-dim ``NamedSharding`` so a slot's cache rows and (paged) pool
    blocks live on exactly one device.  Admission picks the *least-occupied
    eligible* shard (free slot + that shard's block budget), lowest shard id
    breaking ties, so no shard idles while another queues; backfill after a
    retirement is shard-local by construction (the freed slot stays in its
    group).  FIFO order is preserved: requests are still admitted strictly in
    submission order, only the slot each one lands on changes.
    """

    def __init__(self, batch_size: int, kv: Optional[PagedKV] = None,
                 max_pending: Optional[int] = None, n_shards: int = 1):
        assert n_shards >= 1 and batch_size % n_shards == 0, \
            f"batch_size {batch_size} not divisible by n_shards {n_shards}"
        if kv is not None:
            assert kv.n_shards == n_shards, "scheduler/kv shard count mismatch"
        self.batch_size = batch_size
        self.n_shards = n_shards
        self.shard_size = batch_size // n_shards
        self.kv = kv
        self.max_pending = max_pending       # None = unbounded FIFO
        self.queue: deque = deque()          # (rid, req) awaiting a slot
        self.slots: List[Optional[Slot]] = [None] * batch_size
        self._next_rid = 0

    # -- queue ---------------------------------------------------------------
    def submit(self, req) -> int:
        if self.max_pending is not None and len(self.queue) >= self.max_pending:
            raise RejectedError(
                f"pending queue full ({len(self.queue)} >= "
                f"max_pending={self.max_pending}): shed load or retry")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, req))
        return rid

    @property
    def pending(self) -> int:
        return len(self.queue)

    def peek_pending(self):
        return self.queue[0]

    def pop_pending(self):
        return self.queue.popleft()

    def remove_pending(self, rid: int):
        """Pull a not-yet-admitted request out of the FIFO (cancellation).
        Returns its GenRequest, or None if `rid` is not queued."""
        for i, (qrid, req) in enumerate(self.queue):
            if qrid == rid:
                del self.queue[i]
                return req
        return None

    # -- slots ---------------------------------------------------------------
    def shard_of(self, slot_id: int) -> int:
        return slot_id // self.shard_size

    def free_slot(self, shard: Optional[int] = None) -> Optional[int]:
        """First free slot — within `shard`'s group when given."""
        lo = 0 if shard is None else shard * self.shard_size
        hi = self.batch_size if shard is None else lo + self.shard_size
        for i in range(lo, hi):
            if self.slots[i] is None:
                return i
        return None

    def shard_active(self, shard: int) -> int:
        """Occupied slots in `shard`'s group."""
        lo = shard * self.shard_size
        return sum(s is not None
                   for s in self.slots[lo:lo + self.shard_size])

    def pick_shard(self, prompt_len: int, max_new: int) -> Optional[int]:
        """Admission target: the least-occupied shard with a free slot whose
        (paged) block budget covers the request; lowest shard id breaks ties.
        None when no shard is eligible.  With n_shards == 1 this is exactly
        the old can_admit condition (shard 0 or None)."""
        best = None
        for sh in range(self.n_shards):
            if self.free_slot(sh) is None:
                continue
            if self.kv is not None and \
                    not self.kv.can_admit(prompt_len, max_new, shard=sh):
                continue
            occ = self.shard_active(sh)
            if best is None or occ < best[0]:
                best = (occ, sh)
        return None if best is None else best[1]

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """Some shard has a free slot and (paged) the block budget."""
        return self.pick_shard(prompt_len, max_new) is not None

    def place(self, slot_id: int, slot: Slot) -> None:
        assert self.slots[slot_id] is None, f"slot {slot_id} occupied"
        self.slots[slot_id] = slot

    def retire(self, slot_id: int) -> Slot:
        slot = self.slots[slot_id]
        assert slot is not None
        self.slots[slot_id] = None
        return slot

    def active_slots(self):
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def slot_of(self, rid: int) -> Optional[int]:
        """Slot id currently bound to request `rid`, or None."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                return i
        return None

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        return self.num_active > 0 or self.pending > 0

    # -- paged-KV block tables ----------------------------------------------
    def kv_admit(self, slot_id: int, prompt_len: int, max_new: int) -> bool:
        """Allocate prompt blocks + decode reservation for an admission."""
        return self.kv is None or self.kv.admit(slot_id, prompt_len, max_new)

    def kv_ensure(self, slot_id: int, pos: int) -> bool:
        """Append-on-decode: make `pos` writable. True if the table changed."""
        return self.kv is not None and self.kv.ensure(slot_id, pos)

    def kv_release(self, slot_id: int):
        """Free a retiring slot's blocks; returns (global ids, ring ids)."""
        if self.kv is None:
            return [], []
        return self.kv.release(slot_id)
