"""Paged KV cache: block pool allocator + per-slot block tables (host side).

Instead of one contiguous ``(batch, max_len, ...)`` KV region per slot, the
paged cache is a shared pool of fixed-size blocks per attention layer:

* device side — each attention layer's cache is ``(num_blocks + 1, block_size,
  kv_heads, head_dim)``.  Block id ``b`` names row ``b`` of every same-kind
  layer's pool (vLLM-style: one id space, per-layer storage).  Row
  ``num_blocks`` is the **zero block**: it is never allocated and never
  written, so gathering through an unallocated table entry reads exact zeros —
  bit-identical to the zero-initialized contiguous cache.  Scatter sentinel
  ``num_blocks + 1`` is out of bounds and dropped (``mode="drop"``).
* host side — this module.  :class:`BlockPool` is the free-list allocator
  with *reservation credits*: admission allocates the prompt's blocks and
  reserves the decode worst case, so a request admitted once can never hit an
  out-of-blocks condition mid-decode (``append`` only converts credits).
  :class:`PagedKV` bundles the two id spaces (global/cross layers vs
  sliding-window ring layers) with the per-slot block tables the decode step
  gathers through.

The scheduler drives this state: allocate on admission, append on decode when
a slot's position crosses a block boundary, free (and zero, on device) on
retirement.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class BlockPool:
    """Fixed-capacity block allocator with reservation credits.

    ``alloc(owner, n, reserve=r)`` either hands out ``n`` block ids and
    earmarks ``r`` more for later ``append(owner)`` calls, or returns ``None``
    without any side effects (admission refusal must leave the pool
    consistent).  Free blocks backing reservations are not admission headroom:
    ``num_free`` already subtracts outstanding credits.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 0 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}

    # -- queries -------------------------------------------------------------
    def blocks_for(self, positions: int) -> int:
        """Blocks needed to hold `positions` cache positions."""
        return -(-max(int(positions), 0) // self.block_size)

    @property
    def num_reserved(self) -> int:
        return sum(self._reserved.values())

    @property
    def num_free(self) -> int:
        """Admission headroom: free blocks not backing a reservation."""
        return len(self._free) - self.num_reserved

    @property
    def num_owned(self) -> int:
        return sum(len(ids) for ids in self._owned.values())

    def owned(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, []))

    def can(self, blocks: int) -> bool:
        return self.num_free >= blocks

    # -- mutation ------------------------------------------------------------
    def alloc(self, owner: int, blocks: int, reserve: int = 0
              ) -> Optional[List[int]]:
        assert owner not in self._owned, f"owner {owner} already holds blocks"
        if self.num_free < blocks + reserve:
            return None
        ids = [self._free.pop() for _ in range(blocks)]
        self._owned[owner] = ids
        if reserve:
            self._reserved[owner] = reserve
        return list(ids)

    def append(self, owner: int) -> int:
        """Convert one of `owner`'s reservation credits into a block."""
        assert self._reserved.get(owner, 0) > 0, \
            f"owner {owner} has no reserved blocks left"
        self._reserved[owner] -= 1
        bid = self._free.pop()            # safe: alloc() kept credits backed
        self._owned[owner].append(bid)
        return bid

    def free(self, owner: int) -> List[int]:
        """Release all of `owner`'s blocks and credits; returns the block ids."""
        ids = self._owned.pop(owner, [])
        self._reserved.pop(owner, None)
        self._free.extend(ids)
        return ids

    def check(self) -> None:
        """Conservation invariant: every block is free xor owned, exactly once."""
        owned = [b for ids in self._owned.values() for b in ids]
        assert len(set(owned)) == len(owned), "double-allocated block"
        assert sorted(owned + self._free) == list(range(self.num_blocks)), \
            "block leak/duplication"
        assert len(self._free) >= self.num_reserved, "unbacked reservation"


class PagedKV:
    """Host-side paged-KV state: two block-id spaces + per-slot block tables.

    * ``pool_g`` / ``table_g`` — global-attention (and cross-attention) layers:
      a slot's table row maps logical positions ``[0, max_len)`` to blocks,
      ``table_g[slot, j]`` holding positions ``[j*bs, (j+1)*bs)``.
    * ``pool_l`` / ``table_l`` — sliding-window ring layers: the ring's
      ``ring_len`` slots are paged the same way (all blocks allocated at
      admission — ring writes wrap, so the table never grows).

    Host tables store ``-1`` for unallocated; device views substitute the
    gather sentinel (the zero block) or the scatter sentinel (out of bounds).
    """

    def __init__(self, batch_size: int, max_len: int, block_size: int,
                 num_blocks: int, ring_len: int = 0, num_ring_blocks: int = 0):
        self.batch_size = batch_size
        self.max_len = max_len
        self.block_size = block_size
        self.ring_len = ring_len
        self.pool_g = BlockPool(num_blocks, block_size)
        self.pool_l = BlockPool(num_ring_blocks, block_size) if ring_len else None
        self.width_g = self.pool_g.blocks_for(max_len)
        self.width_l = self.pool_g.blocks_for(ring_len) if ring_len else 1
        self.table_g = np.full((batch_size, self.width_g), -1, np.int64)
        self.table_l = np.full((batch_size, self.width_l), -1, np.int64)

    # -- admission sizing ----------------------------------------------------
    def needs(self, prompt_len: int, max_new: int) -> Tuple[int, int, int]:
        """(global alloc, global reserve, ring alloc) block counts for a
        request prefilled at `prompt_len` generating up to `max_new` tokens.

        Decode writes positions ``[prompt_len, prompt_len + max_new - 1)``
        (the first token comes from prefill), clipped to ``max_len``.
        """
        total = min(prompt_len + max_new - 1, self.max_len)
        ga = self.pool_g.blocks_for(prompt_len)
        gr = self.pool_g.blocks_for(total) - ga
        la = self.pool_l.blocks_for(self.ring_len) if self.pool_l else 0
        return ga, gr, la

    def fits(self, prompt_len: int, max_new: int) -> bool:
        """Whether the request could ever be admitted on an empty pool."""
        ga, gr, la = self.needs(prompt_len, max_new)
        ok = self.pool_g.num_blocks >= ga + gr
        if self.pool_l is not None:
            ok = ok and self.pool_l.num_blocks >= la
        return ok

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        ga, gr, la = self.needs(prompt_len, max_new)
        ok = self.pool_g.can(ga + gr)
        if self.pool_l is not None:
            ok = ok and self.pool_l.can(la)
        return ok

    # -- lifecycle -----------------------------------------------------------
    def admit(self, slot: int, prompt_len: int, max_new: int) -> bool:
        """Allocate prompt blocks + decode reservation for `slot`. All-or-
        nothing: a refusal leaves pools and tables untouched."""
        ga, gr, la = self.needs(prompt_len, max_new)
        ids_g = self.pool_g.alloc(slot, ga, reserve=gr)
        if ids_g is None:
            return False
        if self.pool_l is not None:
            ids_l = self.pool_l.alloc(slot, la)
            if ids_l is None:
                self.pool_g.free(slot)
                return False
            self.table_l[slot, :la] = ids_l
        self.table_g[slot, :ga] = ids_g
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Make position `pos` writable for `slot`, appending a reserved block
        at a block boundary. Returns True if the table changed."""
        j = pos // self.block_size
        if self.table_g[slot, j] >= 0:
            return False
        assert (self.table_g[slot, :j] >= 0).all(), "non-contiguous block table"
        self.table_g[slot, j] = self.pool_g.append(slot)
        return True

    def release(self, slot: int) -> Tuple[List[int], List[int]]:
        """Free `slot`'s blocks (both id spaces) and clear its table rows."""
        g = self.pool_g.free(slot)
        l = self.pool_l.free(slot) if self.pool_l is not None else []
        self.table_g[slot] = -1
        self.table_l[slot] = -1
        return g, l

    # -- device views --------------------------------------------------------
    @property
    def zero_block_g(self) -> int:
        return self.pool_g.num_blocks

    @property
    def zero_block_l(self) -> int:
        return self.pool_l.num_blocks if self.pool_l is not None else 0

    def gather_tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """(B, Tg), (B, Tl) int32 tables for reads: unallocated -> zero block."""
        tg = np.where(self.table_g >= 0, self.table_g,
                      self.zero_block_g).astype(np.int32)
        tl = np.where(self.table_l >= 0, self.table_l,
                      self.zero_block_l).astype(np.int32)
        return tg, tl

    def scatter_rows(self, slot: int) -> Tuple[np.ndarray, np.ndarray]:
        """(Tg,), (Tl,) int32 rows for prefill insert: unallocated -> out of
        bounds (dropped), so the zero block is never written."""
        rg = np.where(self.table_g[slot] >= 0, self.table_g[slot],
                      self.zero_block_g + 1).astype(np.int32)
        rl = np.where(self.table_l[slot] >= 0, self.table_l[slot],
                      self.zero_block_l + 1).astype(np.int32)
        return rg, rl

    def check(self) -> None:
        self.pool_g.check()
        if self.pool_l is not None:
            self.pool_l.check()
