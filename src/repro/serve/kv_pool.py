"""Paged KV cache: refcounted block pool + per-slot block tables (host side).

Instead of one contiguous ``(batch, max_len, ...)`` KV region per slot, the
paged cache is a shared pool of fixed-size blocks per attention layer:

* device side — each attention layer's cache is ``(num_blocks + 1, block_size,
  kv_heads, head_dim)``.  Block id ``b`` names row ``b`` of every same-kind
  layer's pool (vLLM-style: one id space, per-layer storage).  Row
  ``num_blocks`` is the **zero block**: it is never allocated and never
  written, so gathering through an unallocated table entry reads exact zeros —
  bit-identical to the zero-initialized contiguous cache.  Scatter sentinel
  ``num_blocks + 1`` is out of bounds and dropped (``mode="drop"``).
* host side — this module.  :class:`BlockPool` is the allocator with
  *reservation credits*: admission allocates the prompt's blocks and
  reserves the decode worst case, so a request admitted once can never hit an
  out-of-blocks condition mid-decode (``append`` only converts credits).
  :class:`PagedKV` bundles the two id spaces (global/cross layers vs
  sliding-window ring layers) with the per-slot block tables the decode step
  gathers through.

Refcounted prefix caching (PR 5)
--------------------------------
Every allocated block carries a **refcount**; full prompt blocks can be
*registered* under a rolling hash of the token prefix (:func:`prefix_key`:
``key_i = H(key_{i-1}, tokens[i*bs:(i+1)*bs])``).  A request whose prompt
starts with an already-resident registered chain **shares** those blocks
(refcount + 1) instead of re-prefilling them — the EMT analog reads that
produced that K/V are paid once, and admission bills zero incremental
``energy_pj``/``kv_reads`` for the hit.  When the prompt diverges *inside* a
registered block, the shared prefix of that block is reused **copy-on-write**:
a private block is allocated, the donor's rows are device-copied, and prefill
resumes at the divergence offset.  Releasing a shared block only decrements
the refcount; registered blocks whose refcount reaches zero are parked in an
LRU *cached-free* list — still hit-able, evicted (and re-zeroed by the
engine) only when allocation needs the capacity.  Unregistered blocks are
zeroed and blank-freed exactly as before, so with the prefix cache off the
pool behaves bit-identically to the PR 2 allocator.

The scheduler drives this state: allocate on admission, append on decode when
a slot's position crosses a block boundary, free (decref) on retirement.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np


def prefix_key(parent: Optional[bytes], tokens) -> bytes:
    """Rolling hash of one full prompt block, chained through `parent`."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent if parent is not None else b"root")
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


def prefix_keys(prompt, block_size: int) -> List[bytes]:
    """Hash chain over the prompt's *full* blocks (partial tail excluded)."""
    prompt = np.asarray(prompt, np.int32)
    keys, parent = [], None
    for i in range(len(prompt) // block_size):
        parent = prefix_key(parent, prompt[i * block_size:(i + 1) * block_size])
        keys.append(parent)
    return keys


class BlockPool:
    """Fixed-capacity refcounted block allocator with reservation credits.

    ``alloc(owner, n, reserve=r)`` either hands out ``n`` block ids and
    earmarks ``r`` more for later ``append(owner)`` calls, or returns ``None``
    without any side effects (admission refusal must leave the pool
    consistent).  Free blocks backing reservations are not admission headroom:
    ``num_free`` already subtracts outstanding credits.

    Blocks live in exactly one of three states: **blank-free** (zeroed on
    device), **cached-free** (refcount 0 but registered under a prefix key —
    content retained, evictable coldest-first by decayed hit count), or
    **active** (refcount >= 1, possibly shared by several owners).  Eviction
    happens lazily inside allocation;
    evicted ids accumulate until :meth:`pop_evicted` so the engine can zero
    their stale content on device before the new owner writes.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 0 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}
        self._ref: Dict[int, int] = {}              # active blocks only
        # prefix-cache registry
        self._cached: "OrderedDict[int, bytes]" = OrderedDict()  # bid -> key
        self._key_to_block: Dict[bytes, int] = {}
        self._block_key: Dict[int, bytes] = {}      # registered (active+cached)
        self._key_parent: Dict[bytes, Optional[bytes]] = {}
        self._key_tokens: Dict[bytes, np.ndarray] = {}
        self._children: Dict[Optional[bytes], List[bytes]] = {}
        self._evicted: List[int] = []
        # reuse-weighted eviction: each registered block carries a decayed
        # hit count; eviction takes the *coldest* cached block (lowest
        # weight, oldest release breaking ties) instead of blind LRU, and
        # every eviction decays the survivors so ancient popularity fades
        # under sustained churn.  A hot shared prefix therefore survives a
        # stream of cold one-shot prompts that would have rotated it out of
        # a pure LRU (tests/test_prefix_cache.py).
        self._reuse: Dict[int, float] = {}          # bid -> decayed hit count
        self.reuse_decay = 0.9
        # counters (reported by the engine / benchmarks)
        self.hits = 0
        self.evictions = 0

    # -- queries -------------------------------------------------------------
    def blocks_for(self, positions: int) -> int:
        """Blocks needed to hold `positions` cache positions."""
        return -(-max(int(positions), 0) // self.block_size)

    @property
    def num_reserved(self) -> int:
        return sum(self._reserved.values())

    @property
    def num_free(self) -> int:
        """Admission headroom: blank + evictable blocks not backing a
        reservation."""
        return len(self._free) + len(self._cached) - self.num_reserved

    @property
    def num_cached(self) -> int:
        return len(self._cached)

    @property
    def num_owned(self) -> int:
        return sum(len(ids) for ids in self._owned.values())

    def owned(self, owner: int) -> List[int]:
        return list(self._owned.get(owner, []))

    def refcount(self, bid: int) -> int:
        return self._ref.get(bid, 0)

    def can(self, blocks: int) -> bool:
        return self.num_free >= blocks

    # -- prefix-cache registry -----------------------------------------------
    def lookup(self, key: bytes) -> Optional[int]:
        return self._key_to_block.get(key)

    def key_tokens(self, key: bytes) -> Optional[np.ndarray]:
        return self._key_tokens.get(key)

    def key_of(self, bid: int) -> Optional[bytes]:
        return self._block_key.get(bid)

    def children(self, parent: Optional[bytes]) -> List[bytes]:
        """Keys registered directly under `parent` (partial-tail donors)."""
        return [k for k in self._children.get(parent, ())
                if k in self._key_to_block]

    def register(self, bid: int, key: bytes, parent: Optional[bytes],
                 tokens) -> bool:
        """Register a *fully written* block under its prefix key.

        First registration wins (a duplicate key keeps pointing at the block
        already serving hits); a block has at most one key."""
        if key in self._key_to_block or bid in self._block_key:
            return False
        assert self.refcount(bid) >= 1, "registering an unallocated block"
        self._key_to_block[key] = bid
        self._block_key[bid] = key
        self._key_parent[key] = parent
        self._key_tokens[key] = np.ascontiguousarray(tokens, np.int32).copy()
        self._children.setdefault(parent, []).append(key)
        self._reuse[bid] = 0.0
        return True

    def reuse_weight(self, bid: int) -> float:
        """Decayed hit count driving eviction order (registered blocks)."""
        return self._reuse.get(bid, 0.0)

    def _unregister(self, bid: int) -> None:
        self._reuse.pop(bid, None)
        key = self._block_key.pop(bid)
        del self._key_to_block[key]
        parent = self._key_parent.pop(key)
        self._key_tokens.pop(key)
        self._children[parent].remove(key)
        if not self._children[parent]:
            del self._children[parent]

    # -- mutation ------------------------------------------------------------
    def _take_block(self, avoid=()) -> Optional[int]:
        """Pop a blank block; if none, evict the *coldest* cached-free block
        (lowest decayed hit count, oldest release breaking ties) and decay
        the survivors' weights."""
        if self._free:
            return self._free.pop()
        victim = None
        for idx, bid in enumerate(self._cached):    # idx = release order
            if bid in avoid:
                continue
            rank = (self._reuse.get(bid, 0.0), idx)
            if victim is None or rank < victim[0]:
                victim = (rank, bid)
        if victim is None:
            return None
        bid = victim[1]
        del self._cached[bid]
        self._unregister(bid)
        self._evicted.append(bid)
        self.evictions += 1
        for other in self._cached:
            self._reuse[other] *= self.reuse_decay
        return bid

    def pop_evicted(self) -> List[int]:
        """Block ids evicted from the prefix cache since the last call — their
        device content is stale and must be zeroed before the new owner's
        first gather-visible write."""
        out, self._evicted = self._evicted, []
        return out

    def alloc(self, owner: int, blocks: int, reserve: int = 0,
              extend: bool = False, avoid=()) -> Optional[List[int]]:
        assert extend or owner not in self._owned, \
            f"owner {owner} already holds blocks"
        if self.num_free < blocks + reserve:
            return None
        taken: List[int] = []
        for _ in range(blocks):
            bid = self._take_block(avoid=avoid)
            if bid is None:                         # only avoided evictables
                self._free.extend(taken)
                return None
            taken.append(bid)
        held = self._owned.setdefault(owner, [])
        for bid in taken:
            self._ref[bid] = 1
            held.append(bid)
        if reserve:
            self._reserved[owner] = self._reserved.get(owner, 0) + reserve
        return list(taken)

    def acquire(self, owner: int, bid: int) -> None:
        """Share an existing block with `owner` (prefix-cache hit): bump the
        refcount, reviving it from the cached-free list if parked there."""
        if bid in self._cached:
            del self._cached[bid]
            self._ref[bid] = 1
        else:
            assert self._ref.get(bid, 0) >= 1, f"block {bid} is blank-free"
            self._ref[bid] += 1
        self._owned.setdefault(owner, []).append(bid)
        self.hits += 1
        self._reuse[bid] = self._reuse.get(bid, 0.0) + 1.0

    def append(self, owner: int) -> int:
        """Convert one of `owner`'s reservation credits into a block."""
        assert self._reserved.get(owner, 0) > 0, \
            f"owner {owner} has no reserved blocks left"
        self._reserved[owner] -= 1
        bid = self._take_block()         # safe: alloc() kept credits backed
        assert bid is not None
        self._ref[bid] = 1
        self._owned[owner].append(bid)
        return bid

    def free(self, owner: int) -> List[int]:
        """Drop `owner`'s references and credits.  Returns the ids that became
        **blank** (refcount hit zero, unregistered) — those must be zeroed on
        device; registered blocks park in the cached-free LRU instead and
        shared blocks simply lose one reference."""
        blanks: List[int] = []
        for bid in self._owned.pop(owner, []):
            self._ref[bid] -= 1
            if self._ref[bid] > 0:
                continue
            del self._ref[bid]
            if bid in self._block_key:
                self._cached[bid] = self._block_key[bid]
            else:
                self._free.append(bid)
                blanks.append(bid)
        self._reserved.pop(owner, None)
        return blanks

    def check(self) -> None:
        """Conservation: every block is blank xor cached xor active (exactly
        once), refcounts equal the number of owner references, reservations
        are backed, and the registry is consistent."""
        active = sorted(self._ref)
        assert all(self._ref[b] >= 1 for b in active), "zombie refcount"
        assert not (set(active) & set(self._free)), "block both active+free"
        assert not (set(active) & set(self._cached)), "block both active+cached"
        assert not (set(self._free) & set(self._cached)), "free+cached overlap"
        assert sorted(active + self._free + list(self._cached)) == \
            list(range(self.num_blocks)), "block leak/duplication"
        refs: Dict[int, int] = {}
        for ids in self._owned.values():
            for b in ids:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self._ref, "refcount != owner references"
        assert len(self._free) + len(self._cached) >= self.num_reserved, \
            "unbacked reservation"
        assert set(self._key_to_block.values()) == set(self._block_key), \
            "registry out of sync"
        for bid in self._cached:
            assert bid in self._block_key, "cached block without a key"
        assert set(self._reuse) == set(self._block_key), \
            "reuse weights out of sync with the registry"


class PagedKV:
    """Host-side paged-KV state: two block-id spaces + per-slot block tables.

    * ``pool_g`` / ``table_g`` — global-attention (and cross-attention) layers:
      a slot's table row maps logical positions ``[0, max_len)`` to blocks,
      ``table_g[slot, j]`` holding positions ``[j*bs, (j+1)*bs)``.
    * ``pool_l`` / ``table_l`` — sliding-window ring layers: the ring's
      ``ring_len`` slots are paged the same way (all blocks allocated at
      admission — ring writes wrap, so the table never grows).

    Host tables store ``-1`` for unallocated; device views substitute the
    gather sentinel (the zero block) or the scatter sentinel (out of bounds).

    Prefix caching operates on the **global** pool only (ring content is a
    positional window of the request's own stream and recurrent state cannot
    be shared — the engine refuses ``prefix_cache=True`` for such stacks).

    Data-parallel serving (``n_shards > 1``)
    ----------------------------------------
    The slot range is partitioned into ``n_shards`` contiguous groups of
    ``batch_size // n_shards`` slots, and each group gets its **own**
    BlockPool(s) of ``num_blocks // n_shards`` blocks.  Table entries store
    ids *local to the slot's shard pool* — on device each shard holds only
    its own pool rows (plus its own zero block), so every gather/scatter the
    block table drives resolves shard-locally and the sharded decode step
    never needs a cross-device collective.  Free lists, refcounts, and the
    prefix registry are per shard: a prefix-cache lookup only sees chains
    registered in the *same* shard's pool; a prompt that would have hit a
    chain resident on a different shard counts into
    ``cross_shard_prefix_misses`` instead (locality observability for the
    scheduler's shard-assignment policy).  ``n_shards == 1`` (the default)
    is exactly the old single-pool behavior.
    """

    def __init__(self, batch_size: int, max_len: int, block_size: int,
                 num_blocks: int, ring_len: int = 0, num_ring_blocks: int = 0,
                 n_shards: int = 1):
        assert n_shards >= 1 and batch_size % n_shards == 0, \
            f"batch_size {batch_size} not divisible by n_shards {n_shards}"
        assert num_blocks % n_shards == 0, \
            f"num_blocks {num_blocks} not divisible by n_shards {n_shards}"
        self.batch_size = batch_size
        self.max_len = max_len
        self.block_size = block_size
        self.ring_len = ring_len
        self.n_shards = n_shards
        self.shard_size = batch_size // n_shards
        self.pools_g = [BlockPool(num_blocks // n_shards, block_size)
                        for _ in range(n_shards)]
        if ring_len:
            assert num_ring_blocks % n_shards == 0, \
                (f"num_ring_blocks {num_ring_blocks} not divisible by "
                 f"n_shards {n_shards}")
            self.pools_l = [BlockPool(num_ring_blocks // n_shards, block_size)
                            for _ in range(n_shards)]
        else:
            self.pools_l = None
        self.width_g = self.pools_g[0].blocks_for(max_len)
        self.width_l = self.pools_g[0].blocks_for(ring_len) if ring_len else 1
        self.table_g = np.full((batch_size, self.width_g), -1, np.int64)
        self.table_l = np.full((batch_size, self.width_l), -1, np.int64)
        # prompts that broke their hash walk on a chain resident in a
        # *different* shard's registry (would have hit with co-located
        # scheduling; see class docstring)
        self.cross_shard_prefix_misses = 0
        # per-slot prefix bookkeeping: the hash chain of the slot's full
        # written-stream blocks + the tokens behind it (register_filled)
        self._chains: Dict[int, List[bytes]] = {}
        self._chain_tokens: Dict[int, np.ndarray] = {}

    # -- shard routing -------------------------------------------------------
    def shard_of(self, slot: int) -> int:
        return slot // self.shard_size

    @property
    def pool_g(self) -> BlockPool:
        """The slot-shard-0 global pool (the *only* pool when n_shards == 1;
        sharded callers iterate ``pools_g``)."""
        return self.pools_g[0]

    @property
    def pool_l(self) -> Optional[BlockPool]:
        return self.pools_l[0] if self.pools_l is not None else None

    @property
    def prefix_hits(self) -> int:
        return sum(p.hits for p in self.pools_g)

    @property
    def prefix_evictions(self) -> int:
        return sum(p.evictions for p in self.pools_g)

    # -- admission sizing ----------------------------------------------------
    def needs(self, prompt_len: int, max_new: int) -> Tuple[int, int, int]:
        """(global alloc, global reserve, ring alloc) block counts for a
        request prefilled at `prompt_len` generating up to `max_new` tokens.

        Decode writes positions ``[prompt_len, prompt_len + max_new - 1)``
        (the first token comes from prefill), clipped to ``max_len``.
        """
        total = min(prompt_len + max_new - 1, self.max_len)
        ga = self.pool_g.blocks_for(prompt_len)
        gr = self.pool_g.blocks_for(total) - ga
        la = self.pool_l.blocks_for(self.ring_len) if self.pool_l else 0
        return ga, gr, la

    def fits(self, prompt_len: int, max_new: int) -> bool:
        """Whether the request could ever be admitted on an empty pool
        (sharded: on one shard's empty pool — a request never spans pools)."""
        ga, gr, la = self.needs(prompt_len, max_new)
        ok = self.pool_g.num_blocks >= ga + gr
        if self.pool_l is not None:
            ok = ok and self.pool_l.num_blocks >= la
        return ok

    def can_admit(self, prompt_len: int, max_new: int,
                  shard: Optional[int] = None) -> bool:
        """Block budget check: against `shard`'s pools, or any shard's."""
        ga, gr, la = self.needs(prompt_len, max_new)
        shards = range(self.n_shards) if shard is None else (shard,)
        for s in shards:
            ok = self.pools_g[s].can(ga + gr)
            if self.pools_l is not None:
                ok = ok and self.pools_l[s].can(la)
            if ok:
                return True
        return False

    # -- lifecycle -----------------------------------------------------------
    def admit(self, slot: int, prompt_len: int, max_new: int) -> bool:
        """Allocate prompt blocks + decode reservation for `slot` from its
        shard's pools (table entries are shard-local ids). All-or-nothing: a
        refusal leaves pools and tables untouched."""
        sh = self.shard_of(slot)
        ga, gr, la = self.needs(prompt_len, max_new)
        ids_g = self.pools_g[sh].alloc(slot, ga, reserve=gr)
        if ids_g is None:
            return False
        if self.pools_l is not None:
            ids_l = self.pools_l[sh].alloc(slot, la)
            if ids_l is None:
                self.pools_g[sh].free(slot)
                return False
            self.table_l[slot, :la] = ids_l
        self.table_g[slot, :ga] = ids_g
        return True

    def admit_prefix(self, slot: int, prompt, max_new: int) -> Optional[dict]:
        """Admission with prefix-cache reuse (global pool, chunked prefill).

        Walks the prompt's rolling-hash chain over resident registered blocks:
        full-block hits are shared (refcount + 1, no prefill); if the prompt
        diverges *inside* the next registered block, its shared head is reused
        copy-on-write.  At least one prompt position is always left to
        recompute — the last prompt token's logits seed sampling.

        Sharded: the walk only sees the *slot's own shard's* registry (device
        pools hold no other shard's rows).  A walk that breaks on a key whose
        chain is resident in a different shard's registry increments
        ``cross_shard_prefix_misses``.

        Returns ``None`` on refusal (pools untouched) or a dict with
        ``cached_len`` (prompt positions served from cache) and ``cow``
        (``(src, dst)`` block ids to device-copy, or ``None``).  The caller
        must zero ``pop_evicted()`` blocks and perform the COW copy
        before the slot's first step.
        """
        pool = self.pools_g[self.shard_of(slot)]
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        bs = self.block_size
        n = len(prompt)
        keys = prefix_keys(prompt, bs)
        max_cached = n - 1                  # always recompute >= 1 token
        hits: List[int] = []
        parent: Optional[bytes] = None
        for i, key in enumerate(keys):
            if (i + 1) * bs > max_cached:
                break
            bid = pool.lookup(key)
            if bid is None or not np.array_equal(
                    pool.key_tokens(key), prompt[i * bs:(i + 1) * bs]):
                if any(p is not pool and p.lookup(key) is not None
                       and np.array_equal(p.key_tokens(key),
                                          prompt[i * bs:(i + 1) * bs])
                       for p in self.pools_g):
                    self.cross_shard_prefix_misses += 1
                break
            hits.append(bid)
            parent = key
        k = len(hits)
        # partial-tail donor: a registered sibling block sharing >= 1 leading
        # token of our block-k tail gets reused copy-on-write
        cow_src, m = None, 0
        cap = min(max_cached - k * bs, bs, n - k * bs)
        if cap > 0:
            tail = prompt[k * bs:k * bs + cap]
            for ck in pool.children(parent):
                ctoks = pool.key_tokens(ck)
                mm = int(np.argmin(np.concatenate(
                    [ctoks[:len(tail)] == tail, [False]])))
                if mm > m:
                    m, cow_src = mm, pool.lookup(ck)

        ga, gr, _ = self.needs(n, max_new)
        fresh = ga - k
        if not pool.can(fresh + gr):
            return None
        for bid in hits:
            pool.acquire(slot, bid)
        avoid = (cow_src,) if cow_src is not None else ()
        ids = pool.alloc(slot, fresh, reserve=gr, extend=True,
                         avoid=avoid)
        if ids is None and cow_src is not None:
            # the only evictable block was the donor: forgo the COW reuse
            cow_src, m = None, 0
            ids = pool.alloc(slot, fresh, reserve=gr, extend=True)
        if ids is None:
            pool.free(slot)
            return None
        self.table_g[slot, :k] = hits
        self.table_g[slot, k:ga] = ids
        cached_len = k * bs + m
        self._chains[slot] = keys
        self._chain_tokens[slot] = prompt
        return {"cached_len": cached_len,
                "cow": (cow_src, ids[0]) if cow_src is not None else None}

    def register_filled(self, slot: int, filled: int, stream=None) -> None:
        """Register the slot's fully-written blocks (write frontier at
        `filled` tokens) so later admissions can share them.

        With `stream=None` this covers the prompt blocks as prefill advances
        (the hash chain was computed at admission).  Decode-block
        registration passes the full written stream — ``prompt ++ generated``
        up to the frontier — and the chain is *extended* past the prompt with
        the generated tokens' rolling hashes, so an identical few-shot
        continuation (same prompt, same greedy continuation) later admits
        against the decode-written blocks too."""
        keys = self._chains.get(slot)
        if keys is None:
            return
        bs = self.block_size
        if stream is not None:
            stream = np.asarray(stream, np.int32).reshape(-1)
            assert len(stream) >= filled, "stream shorter than write frontier"
            tokens = stream
            self._chain_tokens[slot] = stream
            while (len(keys) + 1) * bs <= len(stream):
                i = len(keys)
                keys.append(prefix_key(keys[-1] if keys else None,
                                       stream[i * bs:(i + 1) * bs]))
        else:
            tokens = self._chain_tokens[slot]
        pool = self.pools_g[self.shard_of(slot)]
        for i in range(min(filled // bs, len(keys))):
            bid = int(self.table_g[slot, i])
            if pool.key_of(bid) is not None:
                continue                        # hit or already registered
            pool.register(
                bid, keys[i], keys[i - 1] if i else None,
                tokens[i * bs:(i + 1) * bs])

    def ensure(self, slot: int, pos: int) -> bool:
        """Make position `pos` writable for `slot`, appending a reserved block
        at a block boundary. Returns True if the table changed."""
        j = pos // self.block_size
        if self.table_g[slot, j] >= 0:
            return False
        assert (self.table_g[slot, :j] >= 0).all(), "non-contiguous block table"
        self.table_g[slot, j] = self.pools_g[self.shard_of(slot)].append(slot)
        return True

    def release(self, slot: int) -> Tuple[List[int], List[int]]:
        """Drop `slot`'s block references and clear its table rows.  Returns
        the (global, ring) ids that became blank — the engine zeroes those;
        shared / prefix-cached blocks survive with their content."""
        sh = self.shard_of(slot)
        g = self.pools_g[sh].free(slot)
        l = self.pools_l[sh].free(slot) if self.pools_l is not None else []
        self.table_g[slot] = -1
        self.table_l[slot] = -1
        self._chains.pop(slot, None)
        self._chain_tokens.pop(slot, None)
        return g, l

    # -- device views --------------------------------------------------------
    # zero/sentinel ids are *shard-local* and identical on every shard (all
    # pools are the same size), so the device views below need no shard logic
    @property
    def zero_block_g(self) -> int:
        return self.pool_g.num_blocks

    @property
    def zero_block_l(self) -> int:
        return self.pool_l.num_blocks if self.pool_l is not None else 0

    def gather_tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """(B, Tg), (B, Tl) int32 tables for reads: unallocated -> zero block."""
        tg = np.where(self.table_g >= 0, self.table_g,
                      self.zero_block_g).astype(np.int32)
        tl = np.where(self.table_l >= 0, self.table_l,
                      self.zero_block_l).astype(np.int32)
        return tg, tl

    def scatter_rows(self, slot: int) -> Tuple[np.ndarray, np.ndarray]:
        """(Tg,), (Tl,) int32 rows for prefill insert: unallocated -> out of
        bounds (dropped), so the zero block is never written."""
        rg = np.where(self.table_g[slot] >= 0, self.table_g[slot],
                      self.zero_block_g + 1).astype(np.int32)
        rl = np.where(self.table_l[slot] >= 0, self.table_l[slot],
                      self.zero_block_l + 1).astype(np.int32)
        return rg, rl

    def pop_evicted_g(self) -> List[List[int]]:
        """Per-shard lists of global-pool ids evicted since the last call."""
        return [p.pop_evicted() for p in self.pools_g]

    def check(self) -> None:
        for p in self.pools_g:
            p.check()
        if self.pools_l is not None:
            for p in self.pools_l:
                p.check()
        # table entries must name blocks owned by the slot in its own shard's
        # pool — a cross-shard id would gather another request's K/V rows
        for slot in range(self.batch_size):
            ids = self.table_g[slot][self.table_g[slot] >= 0]
            owned = set(self.pools_g[self.shard_of(slot)].owned(slot))
            assert set(int(b) for b in ids) <= owned, \
                f"slot {slot} table names blocks outside its shard pool"
