"""Serving runtime: sharded prefill/decode steps + a continuous-batching engine.

``serve_step`` (decode) is THE artifact the decode_32k / long_500k dry-run cells
lower: one new token against a seq_len KV cache, with all projections running as
EMT analog (optionally bit-serial, technique C) crossbar reads.

Architecture (continuous batching)
----------------------------------
The engine owns a fixed batch of ``batch_size`` **slots** over one shared KV
cache of shape ``(batch_size, max_len, ...)`` per attention layer.  Each slot
is free or bound to exactly one in-flight request:

* **admission** — a FIFO :class:`~repro.serve.scheduler.Scheduler` assigns the
  queue head to a free slot.  For decoder-only attention stacks (the default,
  ``chunked``) admission just binds the request: its prompt then streams into
  the cache as **chunked prefill** — up to ``prefill_chunk`` tokens per engine
  step at their *exact* positions, directly into the slot's cache region or
  pool blocks, through the same mixed step that decodes the other slots
  (:func:`repro.models.lm.chunk_step`, per-slot phase mask).  There is no
  power-of-two prompt bucket and no separate batch-1 prefill compile; a long
  prompt no longer stalls co-tenant decode while it prefills.  Recurrent /
  enc-dec / mrope stacks keep the legacy path: left-pad into a pow2 bucket,
  prefill alone (batch 1, compiled once per bucket), scatter into the slot.
  Admission happens *mid-decode* either way: nothing recompiles, because the
  step's shapes are static in ``batch_size``.
* **decode** — one jitted step per token for the whole batch.
  :func:`repro.models.lm.decode_step` takes a per-slot ``(B,)`` position vector
  plus an active mask, so slots at different sequence positions share the step;
  retired/free slots flow through the matmuls but their cache rows are frozen.
  While any slot is still streaming its prompt the engine runs the mixed
  chunk step instead (decode-phase slots ride along with ``ntok == 1``).
* **prefix caching** (``prefix_cache=True``; paged + chunked, all-global
  attention) — full prompt blocks are keyed by a rolling hash of the token
  prefix and **refcounted** in the :class:`~repro.serve.kv_pool.BlockPool`.
  A request whose prompt starts with a resident registered chain shares those
  blocks instead of re-prefilling them: the EMT analog reads that produced
  that K/V are paid once, and the hit bills zero incremental ``energy_pj`` /
  ``kv_reads``.  A prompt diverging *inside* a registered block reuses the
  shared head copy-on-write.  Blocks whose refcount drops to zero park in an
  LRU cached-free list (still hit-able) and are evicted + re-zeroed only when
  allocation needs them.
* **sampling** — :mod:`repro.serve.sampling` draws each slot's next token from
  a pure hash of (request seed, generated-token counter): deterministic per
  request, independent of slot placement and co-tenants.
* **retirement** — a slot is released on EOS, ``max_new`` tokens, or cache
  exhaustion (``max_len``), and immediately becomes available for backfill.
  Its cache region (contiguous) or blocks (paged) are zeroed on release so a
  backfilled request can never gather a predecessor's stale K/V.
* **paged KV (``paged=True``)** — instead of a contiguous ``(B, max_len, ...)``
  region per slot, attention layers share a pool of ``block_size``-position
  blocks (:mod:`repro.serve.kv_pool`).  The scheduler keeps a per-request
  block table: prompt blocks + a decode worst-case reservation are allocated
  at admission, one reserved block is drawn each time decode crosses a block
  boundary, and everything is freed at retirement.  Admission is gated on the
  free-block budget as well as a free batch row, so an engine can hold many
  more rows than ``max_len``-sized KV regions — short requests no longer
  strand ``max_len - len`` positions of capacity.  Decode is ONE kernel
  launch per layer by default: the fused paged-attention kernel
  (:mod:`repro.kernels.paged_attention`) scatters the step's new K/V row
  through the block table *inside* the kernel that streams the block tiles
  (``input_output_aliases`` pins the pool update in place) — no separate
  scatter op, no materialized view.  Chunked prefill likewise attends
  table-resolved tiles in a flash-style kernel
  (:mod:`repro.kernels.paged_prefill`) instead of gathering the view per
  chunk.  The only fallback is the explicit kill switch
  (``cfg.fused_paged_attn=False``), which scatters then materializes a view
  clamped to the block-rounded bucket of the furthest live position
  (``view_bucket``), not ``max_len``; M-RoPE configs run the fused path (the
  kernels only see post-RoPE q/k and token-index mask rows).  Unallocated
  entries resolve to a dedicated always-zero block,
  keeping paged decode token-identical to the contiguous cache at
  temperature 0.
* **energy** — the paper's per-step scalar ``energy_pj`` aux is attributed per
  request: prefill energy goes to the admitted request; each decode step's
  energy is split by read counts — every slot (active or idle) issues the same
  crossbar reads per step, so an active slot is billed ``e/batch_size`` and
  the idle rows' share accrues to ``idle_energy_pj`` (scheduler waste, not any
  request's).  Per-request numbers are therefore occupancy-independent, and
  ``sum(per-request) + idle_energy_pj == total_energy_pj`` by construction.

Weight-noise seeding (technique A): with ``fresh_noise=True`` (default) every
decode step folds the global step counter into the EMT fluctuation seed — the
physical RTN picture, matching the pre-continuous-batching engine.  With
``fresh_noise=False`` the fluctuation is frozen at the engine seed (static
programming-noise picture), which makes generation a pure function of the
request — the property the alone-vs-staggered equivalence tests exercise.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models.stack import ATTN_KINDS
from repro.nn.param import param_shardings
from repro.parallel.sharding import RULES, make_shard_fn, cache_shardings
from repro.serve import sampling
from repro.serve.kv_pool import PagedKV
from repro.serve.scheduler import RejectedError, Scheduler, Slot

__all__ = ["ServingEngine", "GenRequest", "GenResult", "RejectedError",
           "prefill_bucket", "view_bucket", "serve_shardings",
           "make_prefill_step", "make_decode_step", "make_serve_decode_step",
           "make_chunk_step", "make_paged_decode_step",
           "make_sharded_chunk_step", "make_sharded_decode_step"]


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def prefill_step(params, batch, cache, seed):
        ctx = Ctx(seed=seed, shard=shard)
        return lm.prefill(params, batch, cfg, ctx, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    """Lockstep decode step (scalar position) — the dry-run lowering artifact."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def decode_step(params, cache, tokens, index, seed):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(params, cache, tokens, index, cfg, ctx)
        return logits, cache, aux["energy_pj"]

    return decode_step


def make_serve_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    """Continuous-batching decode: per-slot positions/active mask + fused
    per-slot seeded sampling. Returns (next_tokens, new_cache, energy_pj)."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def serve_decode_step(params, cache, tokens, index, active, seed,
                          sample_seeds, sample_pos, temps, top_k, top_p,
                          enc_lens):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(params, cache, tokens, index, cfg,
                                            ctx, active=active,
                                            enc_lens=enc_lens)
        next_tok = sampling.sample_tokens(logits, temps, top_k, top_p,
                                          sample_seeds, sample_pos)
        return next_tok, cache, {"energy_pj": aux["energy_pj"],
                                 "corners": aux["corners"],
                                 "kv_reads": aux["kv_reads"]}

    return serve_decode_step


def make_chunk_step(cfg: ModelConfig, mesh: Optional[Mesh], rules,
                    page_lens: Optional[dict] = None):
    """One jitted **mixed prefill+decode** step (lm.chunk_step): every batch
    row advances by `ntok[b]` tokens — a fixed-size chunk of its prompt for
    prefill-phase slots, one generated token for decode-phase slots — and the
    row's last real lane is sampled.  Paged engines additionally pass the
    width-clamped block tables + the static clamped `view_len` (same contract
    as make_paged_decode_step)."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def chunk_step(params, cache, tokens, start, ntok, active, seed,
                   sample_seeds, sample_pos, temps, top_k, top_p,
                   table_g=None, table_l=None, view_len=0):
        ctx = Ctx(seed=seed, shard=shard)
        pt = pl = None
        if page_lens is not None:
            pt = {"global": table_g, "local": table_l}
            pl = lm.clamped_lens(page_lens, view_len)
        logits, cache, aux = lm.chunk_step(params, cache, tokens, start, ntok,
                                           cfg, ctx, active=active,
                                           page_tables=pt, page_lens=pl)
        next_tok = sampling.sample_tokens(logits, temps, top_k, top_p,
                                          sample_seeds, sample_pos)
        return next_tok, cache, {"energy_pj": aux["energy_pj"],
                                 "corners": aux["corners"],
                                 "kv_reads": aux["kv_reads"]}

    return chunk_step


def make_verify_step(cfg: ModelConfig, mesh: Optional[Mesh], rules,
                     page_lens: Optional[dict] = None):
    """Speculative-decoding verify step: one lm.chunk_step over the
    [last_token, draft_1..draft_k] chunk of every slot with `all_lanes=True`,
    returning the per-lane greedy argmax (B, C) — lane j's token is the
    target model's greedy continuation after ..start+j, i.e. the token that
    validates draft j+1 (or replaces it on rejection).  Greedy only: the
    argmax matches sampling.sample_tokens at temperature 0 bit-exactly, which
    is what makes speculative decoding token-identical to plain decode."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def verify_step(params, cache, tokens, start, ntok, active, seed,
                    table_g=None, table_l=None, view_len=0):
        ctx = Ctx(seed=seed, shard=shard)
        pt = pl = None
        if page_lens is not None:
            pt = {"global": table_g, "local": table_l}
            pl = lm.clamped_lens(page_lens, view_len)
        logits, cache, aux = lm.chunk_step(params, cache, tokens, start, ntok,
                                           cfg, ctx, active=active,
                                           page_tables=pt, page_lens=pl,
                                           all_lanes=True)
        greedy = jnp.argmax(logits.astype(jnp.float32), axis=-1) \
                    .astype(jnp.int32)
        return greedy, cache, {"energy_pj": aux["energy_pj"],
                               "corners": aux["corners"],
                               "kv_reads": aux["kv_reads"]}

    return verify_step


def make_pool_copy(cfg: ModelConfig):
    """Copy one global-pool block row src -> dst across every attention
    layer's K/V pools — the device half of prefix-cache copy-on-write (the
    donor block's leading rows are our prompt's K/V verbatim; the diverging
    tail is overwritten by the resuming prefill and never mask-visible)."""
    kinds = cfg.blocks()

    def copy(big, src, dst):
        out = {}
        for i, kind in enumerate(kinds):
            name = f"layer_{i:03d}"
            b = big[name]
            if kind in ATTN_KINDS:
                out[name] = {key: e.at[dst].set(e[src]) for key, e in b.items()}
            else:
                out[name] = b
        return out

    return copy


def view_bucket(need: int, block_size: int, max_len: int) -> int:
    """Block-rounded power-of-two view length covering `need` positions.

    The paged decode step is jit-static in the logical view length; bucketing
    the clamp to power-of-two block counts bounds recompiles at O(log
    max_len/block_size) while still shrinking masks, gathers, and the fused
    kernel's chunk walk to what live requests actually occupy."""
    nb = 1
    while nb * block_size < need:
        nb *= 2
    return nb * block_size if nb * block_size < max_len else max_len


def make_paged_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules,
                           page_lens: dict):
    """Continuous-batching decode against the paged block-table KV cache:
    same contract as make_serve_decode_step plus the (B, T) block tables
    (width-clamped by the caller) and the static clamped `view_len` the
    tables/masks cover this step (lm.clamped_lens; jit once per bucket)."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def paged_decode_step(params, cache, tokens, index, active, seed,
                          sample_seeds, sample_pos, temps, top_k, top_p,
                          enc_lens, table_g, table_l, view_len):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(
            params, cache, tokens, index, cfg, ctx, active=active,
            page_tables={"global": table_g, "local": table_l},
            page_lens=lm.clamped_lens(page_lens, view_len), enc_lens=enc_lens)
        next_tok = sampling.sample_tokens(logits, temps, top_k, top_p,
                                          sample_seeds, sample_pos)
        return next_tok, cache, {"energy_pj": aux["energy_pj"],
                                 "corners": aux["corners"],
                                 "kv_reads": aux["kv_reads"]}

    return paged_decode_step


def make_paged_insert(cfg: ModelConfig, block_size: int, page_lens: dict):
    """Scatter a freshly prefilled batch-1 contiguous cache into the pools.

    `row_g`/`row_l` are the slot's block-table rows with unallocated entries
    pointing out of bounds (dropped), so only the request's own blocks are
    written — including their zero padding tails, which clears any stale
    content left by the blocks' previous owner."""
    kinds = cfg.blocks()

    def pad_to_blocks(x, width):
        # (1, L, KV, hd) -> (width, block_size, KV, hd), zero-padded
        pad = width * block_size - x.shape[1]
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        return x[0].reshape(width, block_size, *x.shape[2:])

    def insert(big, small, row_g, row_l, slot):
        out = {}
        for i, kind in enumerate(kinds):
            name = f"layer_{i:03d}"
            b, s = big[name], small[name]
            if kind in ATTN_KINDS:
                ring = kind == "local" and page_lens["ring"]
                e = {}
                for key in b:
                    row = row_g if (key in ("ck", "cv") or not ring) else row_l
                    e[key] = b[key].at[row].set(
                        pad_to_blocks(s[key].astype(b[key].dtype),
                                      row.shape[0]),
                        mode="drop")
                out[name] = e
            else:
                out[name] = jax.tree.map(
                    lambda bb, ss: bb.at[slot].set(ss[0].astype(bb.dtype)),
                    b, s)
        return out

    return insert


def make_paged_zero(cfg: ModelConfig, page_lens: dict):
    """Zero a retiring request's pool blocks (+ its recurrent-state row) so a
    later owner of the same blocks can never gather its stale K/V."""
    kinds = cfg.blocks()

    def zero(big, ids_g, ids_l, slot):
        out = {}
        for i, kind in enumerate(kinds):
            name = f"layer_{i:03d}"
            b = big[name]
            if kind in ATTN_KINDS:
                ring = kind == "local" and page_lens["ring"]
                e = {}
                for key in b:
                    ids = ids_g if (key in ("ck", "cv") or not ring) else ids_l
                    e[key] = b[key].at[ids].set(0.0, mode="drop")
                out[name] = e
            else:
                out[name] = jax.tree.map(lambda bb: bb.at[slot].set(0.0), b)
        return out

    return zero


# --------------------------------------------------------------------------
# Data-parallel (sharded) serving steps.
#
# The engine's sharded mode wraps the *same* per-shard step functions built
# above (with mesh=None — no GSPMD constraints inside) in `shard_map` over
# the mesh "data" axis: params replicated (P()), the cache tree + every
# per-slot (B, ...) argument sharded on dim 0 (P("data")), the noise seed
# replicated.  Each device therefore runs the whole model on its own
# batch_size/n_shards slots against its own pool rows — the paged gathers
# and scatters index *shard-local* block ids by construction, so no table
# resolution ever becomes a cross-device collective (the GSPMD alternative,
# sharding the pool dim of a gathered operand, would all-gather the pools).
# Scalar aux leaves (energy_pj / corners / kv_reads) are lifted to (1,)
# inside the shard, so the stacked output is a (n_shards,) per-shard vector:
# the engine's per-shard energy/idle/corner ledgers come straight off the
# step with no extra collective.
# --------------------------------------------------------------------------


def _shard_stack_aux(aux):
    """Lift scalar aux leaves to (1, ...) so shard_map stacks them into
    per-shard vectors under out_specs=P("data")."""
    return jax.tree.map(lambda e: jnp.asarray(e)[None], aux)


def make_sharded_chunk_step(cfg: ModelConfig, mesh: Mesh,
                            page_lens: Optional[dict] = None):
    """shard_map-SPMD mixed prefill+decode step (see block comment above):
    same contract as make_chunk_step but aux leaves come back as (n_shards,)
    per-shard vectors.  `view_len` stays jit-static (the compiled view width
    is the max over the shards' buckets — SPMD programs share static
    shapes); per-shard clamping happens in the *table values* the engine
    stages (entries past a shard's own bucket resolve to the zero block)."""
    base = make_chunk_step(cfg, None, None, page_lens)
    paged = page_lens is not None
    data, rep = PartitionSpec("data"), PartitionSpec()
    in_specs = (rep, data, data, data, data, data, rep,
                data, data, data, data, data) + ((data, data) if paged else ())
    out_specs = (data, data, data)

    def chunk_step(params, cache, tokens, start, ntok, active, seed,
                   sample_seeds, sample_pos, temps, top_k, top_p,
                   table_g=None, table_l=None, view_len=0):
        def local(params, cache, tokens, start, ntok, active, seed,
                  sample_seeds, sample_pos, temps, top_k, top_p, *tables):
            kw = {"table_g": tables[0], "table_l": tables[1],
                  "view_len": view_len} if paged else {}
            next_tok, cache, aux = base(
                params, cache, tokens, start, ntok, active, seed,
                sample_seeds, sample_pos, temps, top_k, top_p, **kw)
            return next_tok, cache, _shard_stack_aux(aux)

        args = (params, cache, tokens, start, ntok, active, seed,
                sample_seeds, sample_pos, temps, top_k, top_p)
        if paged:
            args += (table_g, table_l)
        return shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)(*args)

    return chunk_step


def make_sharded_decode_step(cfg: ModelConfig, mesh: Mesh,
                             page_lens: Optional[dict] = None):
    """shard_map-SPMD pure-decode step: make_paged_decode_step (paged) /
    make_serve_decode_step (contiguous) per shard, aux stacked per shard."""
    paged = page_lens is not None
    base = make_paged_decode_step(cfg, None, None, page_lens) if paged \
        else make_serve_decode_step(cfg, None, None)
    data, rep = PartitionSpec("data"), PartitionSpec()
    in_specs = (rep, data, data, data, data, rep, data, data, data, data,
                data, data) + ((data, data) if paged else ())
    out_specs = (data, data, data)

    def decode_step(params, cache, tokens, index, active, seed,
                    sample_seeds, sample_pos, temps, top_k, top_p, enc_lens,
                    table_g=None, table_l=None, view_len=0):
        def local(params, cache, tokens, index, active, seed,
                  sample_seeds, sample_pos, temps, top_k, top_p, enc_lens,
                  *tables):
            if paged:
                next_tok, cache, aux = base(
                    params, cache, tokens, index, active, seed,
                    sample_seeds, sample_pos, temps, top_k, top_p, enc_lens,
                    tables[0], tables[1], view_len)
            else:
                next_tok, cache, aux = base(
                    params, cache, tokens, index, active, seed,
                    sample_seeds, sample_pos, temps, top_k, top_p, enc_lens)
            return next_tok, cache, _shard_stack_aux(aux)

        args = (params, cache, tokens, index, active, seed, sample_seeds,
                sample_pos, temps, top_k, top_p, enc_lens)
        if paged:
            args += (table_g, table_l)
        return shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)(*args)

    return decode_step


def make_sharded_paged_zero(cfg: ModelConfig, mesh: Mesh, page_lens: dict):
    """Per-shard zero-on-retire/evict: `(n_shards, W)` id grids + a
    `(n_shards,)` slot vector, one row per shard — non-target shards carry
    the out-of-bounds sentinels and their scatters drop."""
    base = make_paged_zero(cfg, page_lens)
    data = PartitionSpec("data")

    def zero(big, ids_g, ids_l, slot):
        def local(big, ids_g, ids_l, slot):
            return base(big, ids_g[0], ids_l[0], slot[0])
        return shard_map(local, mesh=mesh, in_specs=(data,) * 4,
                         out_specs=data, check_rep=False)(
                             big, ids_g, ids_l, slot)

    return jax.jit(zero, donate_argnums=(0,))


def make_sharded_slot_zero(mesh: Mesh):
    """Contiguous-cache zero-on-retire per shard: `(n_shards,)` local slot
    ids, sentinel (== shard batch size, out of bounds -> dropped) on the
    shards that retire nothing this call."""
    data = PartitionSpec("data")

    def zero(big, slot):
        def local(big, slot):
            return jax.tree.map(
                lambda b: b.at[slot[0]].set(0.0, mode="drop"), big)
        return shard_map(local, mesh=mesh, in_specs=(data, data),
                         out_specs=data, check_rep=False)(big, slot)

    return jax.jit(zero, donate_argnums=(0,))


def make_sharded_pool_copy(cfg: ModelConfig, mesh: Mesh):
    """Per-shard prefix-cache COW copy: `(n_shards,)` src/dst id vectors;
    non-target shards carry the out-of-bounds dst sentinel (update dropped —
    jit scatter semantics — so their gathered src row never lands)."""
    base = make_pool_copy(cfg)
    data = PartitionSpec("data")

    def copy(big, src, dst):
        def local(big, src, dst):
            return base(big, src[0], dst[0])
        return shard_map(local, mesh=mesh, in_specs=(data,) * 3,
                         out_specs=data, check_rep=False)(big, src, dst)

    return jax.jit(copy, donate_argnums=(0,))


def serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                    rules_name: str = "serve_2d"):
    """(param_shardings, cache_shardings, cache_specs) for the serving mesh."""
    rules = RULES[rules_name]
    pspecs = lm.specs(cfg)
    psh = param_shardings(pspecs, mesh, rules)
    cspecs = lm.init_cache_specs(cfg, batch, max_len)
    csh = cache_shardings(cspecs, mesh, rules)
    return psh, csh, cspecs, rules


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    temperature: float = 0.0         # 0 = greedy
    top_k: int = 0                   # 0 = disabled
    top_p: float = 1.0               # >=1 = disabled
    seed: int = 0                    # sampling seed (deterministic per request)
    eos_id: Optional[int] = None     # stop token (None = run to max_new)
    # per-request energy SLA: once the energy billed to this request
    # (prefill + decode + draft) exceeds the budget, the control plane sheds
    # it through the normal cancel path with done_reason="energy_budget"
    # (None = no budget; see serve/control.py)
    energy_budget_uj: Optional[float] = None


@dataclasses.dataclass
class GenResult:
    rid: int                         # request id, submission order
    tokens: np.ndarray               # (n,) int32 generated tokens
    energy_pj: float                 # total EMT energy billed to this request
    prefill_energy_pj: float         # ... of which prefill
    steps: int                       # decode steps the request participated in
    # "eos" | "max_new" | "max_len" | "cancelled" | "timeout" |
    # "energy_budget" — the last three come from ServingEngine.cancel(): the
    # slot retired early with whatever partial tokens/energy it had
    # accumulated (per-request + idle == total energy conservation holds for
    # partials too). "energy_budget" is the control plane shedding a request
    # that exhausted its energy_budget_uj (serve/control.py).
    done_reason: str
    # speculative decoding split (serve/speculative.py; 0 on plain engines):
    # draft_energy_pj is the subset of energy_pj billed on the draft
    # placement; spec_accepted/spec_proposed give the request's accept rate
    draft_energy_pj: float = 0.0
    spec_proposed: int = 0
    spec_accepted: int = 0


def prefill_bucket(n: int, lo: int = 4) -> int:
    """Smallest power-of-two >= n (min `lo`) — prefill compile-cache buckets.

    Sizing note for callers: a request's prompt occupies ``prefill_bucket(len)``
    cache positions (left-padded), so an engine serving prompts of length L for
    ``max_new`` tokens wants ``max_len >= prefill_bucket(L) + max_new - 1``."""
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """Slot-based continuous-batching engine (single host; the sharded steps
    are the same functions the multi-pod dry-run compiles).

    Streaming API: ``submit()`` enqueues a request and returns its rid,
    ``step()`` advances the whole batch one token (admitting queued requests
    into free slots first) and returns any finished :class:`GenResult`s,
    ``drain()`` steps until idle.  ``generate()`` is the batch-mode wrapper.
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int, max_len: int,
                 mesh: Optional[Mesh] = None, rules=None, seed: int = 0,
                 fresh_noise: bool = True, paged: bool = False,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 num_ring_blocks: Optional[int] = None, placement=None,
                 chunked_prefill: Optional[bool] = None,
                 prefill_chunk: int = 16, prefix_cache: bool = False,
                 max_pending: Optional[int] = None,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 controller=None, n_shards: int = 1):
        if placement is not None:
            # heterogeneous device placement (EMTConfig or DevicePlacement):
            # overrides the config's EMT surface for this engine. Params must
            # have been initialized against the same placement.
            cfg = cfg.replace(emt=placement)
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self.fresh_noise = fresh_noise
        # data-parallel serving: slots are partitioned into n_shards groups
        # and every step runs as ONE shard_map SPMD program over the mesh
        # "data" axis — each device owns its group's cache rows / pool blocks
        # (see the sharded-step block comment above).  The mesh must carry a
        # "data" axis of size n_shards (jax.sharding.Mesh over n_shards
        # devices; CI simulates them with
        # XLA_FLAGS=--xla_force_host_platform_device_count=N).
        self.n_shards = int(n_shards)
        if self.n_shards < 1 or batch_size % self.n_shards:
            raise ValueError(f"batch_size {batch_size} not divisible by "
                             f"n_shards {n_shards}")
        self.shard_size = batch_size // self.n_shards
        if self.n_shards > 1:
            if mesh is None:
                from repro.launch.mesh import make_mesh
                mesh = make_mesh(self.n_shards, 1)
            if mesh.shape["data"] != self.n_shards:
                raise ValueError(
                    f"mesh data axis {mesh.shape['data']} != n_shards "
                    f"{self.n_shards}")
        self._mesh = mesh
        self._prefill = jax.jit(make_prefill_step(cfg, mesh, rules))
        self._sample = jax.jit(sampling.sample_tokens)
        # chunked prefill (default for decoder-only attention stacks): prompts
        # stream into the cache in fixed-size chunks through one mixed
        # prefill+decode step at their exact positions — no pow2 prompt
        # buckets, no separate batch-1 prefill compile.  Recurrent stacks
        # (token-serial state), enc-dec (encoder pass), and mrope (3-stream
        # positions) keep the legacy bucketed path.
        can_chunk = (all(k in ATTN_KINDS for k in cfg.blocks())
                     and not cfg.is_encdec and cfg.rope_type != "mrope"
                     and cfg.input_kind != "embeds")
        self.chunked = can_chunk if chunked_prefill is None \
            else bool(chunked_prefill)
        if self.chunked and not can_chunk:
            raise ValueError("chunked_prefill requires a decoder-only "
                             "attention stack without mrope/embeds input")
        if self.n_shards > 1 and not self.chunked:
            raise ValueError("sharded serving (n_shards > 1) requires "
                             "chunked prefill — the legacy bucketed prefill "
                             "path scatters batch-1 caches across shards")
        self.prefill_chunk = int(prefill_chunk)
        assert self.prefill_chunk >= 1
        sharded = self.n_shards > 1
        # paged mode only changes attention caches; pure-recurrent stacks
        # (mamba/xlstm) have nothing to page
        self.paged = bool(paged) and any(k in ATTN_KINDS for k in cfg.blocks())
        if self.paged:
            lens = lm.paged_lens(cfg, max_len)
            ring_len = lens["local"] if lens["ring"] else 0
            wg = -(-max_len // block_size)
            wl = -(-ring_len // block_size) if ring_len else 1
            # default pools: capacity-equal to the contiguous per-slot regions
            if num_blocks is None:
                num_blocks = batch_size * wg
            if num_ring_blocks is None:
                num_ring_blocks = batch_size * wl if ring_len else 0
            self.block_size = block_size
            self.kv = PagedKV(batch_size, max_len, block_size, num_blocks,
                              ring_len, num_ring_blocks if ring_len else 0,
                              n_shards=self.n_shards)
            self.page_lens = lens
            if sharded:
                # device pools hold n_shards * (per-shard blocks + 1 zero
                # block) rows: shard s's rows are its own pool followed by
                # its own zero row, so the (shard-local) gather sentinel
                # kv.zero_block_g and scatter sentinel +1 work unchanged.
                # init_paged_cache adds the one zero row itself, hence the
                # "- 1"; every row starts zeroed, so the NamedSharding
                # device_put is the only placement step needed.
                npb = num_blocks // self.n_shards
                dev_blocks = self.n_shards * (npb + 1) - 1
                dev_ring = 0
                if ring_len:
                    nrb = num_ring_blocks // self.n_shards
                    dev_ring = self.n_shards * (nrb + 1) - 1
                self.cache = lm.init_paged_cache(
                    cfg, batch_size, max_len, block_size, dev_blocks,
                    dev_ring)
                self.cache = jax.device_put(
                    self.cache,
                    NamedSharding(mesh, PartitionSpec("data")))
                self._decode = jax.jit(
                    make_sharded_decode_step(cfg, mesh, lens),
                    donate_argnums=(1,), static_argnames=("view_len",))
                self._chunk = jax.jit(
                    make_sharded_chunk_step(cfg, mesh, lens),
                    donate_argnums=(1,), static_argnames=("view_len",))
                self._zero_retired = make_sharded_paged_zero(cfg, mesh, lens)
                self._insert = None      # chunked admission never scatters
            else:
                self.cache = lm.init_paged_cache(
                    cfg, batch_size, max_len, block_size, num_blocks,
                    num_ring_blocks if ring_len else 0)
                # view_len is static: one compile per power-of-two bucket
                self._decode = jax.jit(
                    make_paged_decode_step(cfg, mesh, rules, lens),
                    donate_argnums=(1,), static_argnames=("view_len",))
                self._insert = jax.jit(
                    make_paged_insert(cfg, block_size, lens),
                    donate_argnums=(0,))
                self._zero_retired = jax.jit(make_paged_zero(cfg, lens),
                                             donate_argnums=(0,))
                if self.chunked:
                    self._chunk = jax.jit(
                        make_chunk_step(cfg, mesh, rules, lens),
                        donate_argnums=(1,),
                        static_argnames=("view_len",))
            self.scheduler = Scheduler(batch_size, kv=self.kv,
                                       max_pending=max_pending,
                                       n_shards=self.n_shards)
        else:
            self.kv = None
            self.cache = lm.init_cache(cfg, batch_size, max_len)
            if sharded:
                self.cache = jax.device_put(
                    self.cache,
                    NamedSharding(mesh, PartitionSpec("data")))
                self._decode = jax.jit(make_sharded_decode_step(cfg, mesh),
                                       donate_argnums=(1,))
                self._chunk = jax.jit(make_sharded_chunk_step(cfg, mesh),
                                      donate_argnums=(1,))
                self._zero_retired = make_sharded_slot_zero(mesh)
                self._insert = None
            else:
                self._decode = jax.jit(
                    make_serve_decode_step(cfg, mesh, rules),
                    donate_argnums=(1,))
                self._insert = jax.jit(self._insert_slot, donate_argnums=(0,))
                self._zero_retired = jax.jit(self._zero_slot,
                                             donate_argnums=(0,))
                if self.chunked:
                    self._chunk = jax.jit(make_chunk_step(cfg, mesh, rules),
                                          donate_argnums=(1,))
            self.scheduler = Scheduler(batch_size, max_pending=max_pending,
                                       n_shards=self.n_shards)
        if sharded:
            # replicate params across the mesh once (weight noise is seeded,
            # so every shard regenerates identical fluctuations per step)
            self.params = jax.device_put(
                self.params, NamedSharding(mesh, PartitionSpec()))
        # refcounted prefix caching: shared prompt-prefix blocks are reused
        # across requests (paged + chunked only; ring/recurrent/enc-dec state
        # cannot be shared across requests, so those stacks are refused)
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache:
            if not (self.paged and self.chunked):
                raise ValueError("prefix_cache requires paged=True and "
                                 "chunked prefill")
            if self.page_lens["ring"]:
                raise ValueError("prefix_cache requires an all-global "
                                 "attention stack (sliding-window ring K/V is "
                                 "positional and cannot be shared)")
            if sharded:
                self._pool_copy = make_sharded_pool_copy(cfg, mesh)
            else:
                self._pool_copy = jax.jit(make_pool_copy(cfg),
                                          donate_argnums=(0,))
        # per-token streaming hook: called as on_token(rid, token) the moment
        # a slot's new token is sampled (inside step()/_chunk_advance, before
        # the request retires) — the async front-end points this at the
        # per-request event queues.  Must be cheap and must not touch the
        # engine (it runs mid-step).
        self.on_token = on_token
        # energy-aware control plane (serve/control.py): gates admission
        # against a rolling per-engine uJ bucket and sheds requests that
        # exhaust their per-request energy_budget_uj (None = no control)
        self.controller = controller
        self.total_energy_pj = 0.0
        self.idle_energy_pj = 0.0    # decode energy of idle slots (waste)
        # per-corner energy totals (prefill + decode), keyed by the placement's
        # corner labels — sums to total_energy_pj by construction
        self.corner_energy_pj = {}
        # per-shard ledgers (length n_shards; a single-shard engine keeps
        # them too, as length-1 views of the same accounting): the sharded
        # step returns each aux scalar as a per-shard vector, so the split
        # is exact — sum(shard_energy_pj) == total_energy_pj and
        # sum(shard_idle_energy_pj) == idle_energy_pj up to summation order.
        self.shard_energy_pj = np.zeros(self.n_shards)
        self.shard_idle_energy_pj = np.zeros(self.n_shards)
        self.shard_corner_energy_pj = {}     # name -> (n_shards,) float64
        self.shard_kv_reads = np.zeros(self.n_shards)
        # occupancy integral: per-shard sum over steps of active slots —
        # min/max over shards is the scheduler's balance metric
        self.shard_occupancy = np.zeros(self.n_shards, np.int64)
        self._steps = 0              # global decode-step counter (noise clock)
        self.peak_concurrent = 0     # high-water mark of active slots
        self._tables_dev = None      # (key, tables) on device (None = stale)
        self.view_len = 0            # last decode step's clamped logical view
        self.shard_view_lens = [0] * self.n_shards   # per-shard view buckets
        # decode + chunk K/V cache elements actually read (mask-visible
        # positions of real lanes only — aux["kv_reads"]); padded/zero-block
        # gathers and chunk padding lanes (clamped duplicate qpos rows) are
        # not billed, identically on the kernel and legacy attend paths
        self.kv_reads_total = 0.0
        # chunked-prefill accounting: prompt tokens actually run through the
        # model vs served straight from the prefix cache (zero energy/reads)
        self.prefill_tokens_total = 0
        self.cached_prefix_tokens = 0

    def _shard_of(self, slot_id: int) -> int:
        return slot_id // self.shard_size

    def _book_corners(self, corners):
        for name, c in corners.items():
            # sharded steps return (n_shards,) per-shard vectors; the legacy
            # paths (and prefill) return scalars, which land on shard 0
            e = np.asarray(c["energy_pj"], np.float64).reshape(-1)
            self.corner_energy_pj[name] = (self.corner_energy_pj.get(name, 0.0)
                                           + float(e.sum()))
            arr = self.shard_corner_energy_pj.setdefault(
                name, np.zeros(self.n_shards))
            if e.size == self.n_shards:
                arr += e
            else:
                arr[0] += float(e.sum())

    # -- jitted helpers ------------------------------------------------------
    @staticmethod
    def _insert_slot(big, small, slot):
        """Scatter a freshly prefilled batch-1 cache into slot `slot` (entries
        shorter than the slot region — e.g. bucketed cross K/V — are
        zero-padded to it)."""
        def put(b, s):
            v = s[0].astype(b.dtype)
            pads = [(0, bd - vd) for bd, vd in zip(b.shape[1:], v.shape)]
            if any(p != (0, 0) for p in pads):
                v = jnp.pad(v, pads)
            return b.at[slot].set(v)

        return jax.tree.map(put, big, small)

    @staticmethod
    def _pad_ids(ids, width: int, sentinel: int) -> np.ndarray:
        """Fixed-width int32 id vector for the jitted zero op: pad the freed
        block ids with the out-of-bounds scatter sentinel (dropped)."""
        out = np.full(width, sentinel, np.int32)
        out[:len(ids)] = ids
        return out

    @staticmethod
    def _zero_slot(big, slot):
        """Zero a retired slot's cache region before the next backfill: the
        full-region prefill scatter used to mask stale reads, but nothing may
        rely on that (partial inserts / paged blocks would leak the previous
        request's K/V)."""
        return jax.tree.map(lambda b: b.at[slot].set(0.0), big)

    # -- streaming API -------------------------------------------------------
    def _bucket_len(self, prompt_len: int) -> int:
        """Cache positions the prompt occupies.  Chunked prefill streams the
        prompt at its exact positions; the legacy one-shot path left-pads into
        a power-of-two bucket (or prefills at exact length when the bucket
        would leave no decode room)."""
        if self.chunked:
            return prompt_len
        S = prefill_bucket(prompt_len)
        return prompt_len if S >= self.max_len else S

    def validate(self, req: GenRequest) -> np.ndarray:
        """Hard request validation — every guard is a ValueError, never a bare
        assert (asserts are stripped under ``python -O``; the ``kv.fits``
        guard was made a hard error for exactly this reason and the rest must
        match).  Returns the normalized (S,) int32 prompt.

        Reads only static engine state (config, pool capacity), never the
        mutable queue/slot tables — the streaming front-end calls it from the
        submitting thread to reject bad requests synchronously before they
        cross into the driver loop.
        """
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if not 1 <= len(prompt) <= self.max_len:
            raise ValueError(f"prompt length {len(prompt)} out of range "
                             f"[1, max_len={self.max_len}]")
        S = self._bucket_len(len(prompt))
        if S > self.max_len:
            # legacy bucketed prefill left-pads into prefill_bucket(L)
            # positions (see the sizing note on prefill_bucket): a bucket
            # wider than max_len would overrun the slot's cache region.
            # _bucket_len clamps near-capacity buckets to the exact prompt
            # length, so this is unreachable unless that clamp regresses —
            # keep the hard guard so an overrun can never reach the cache.
            raise ValueError(f"prompt bucket {S} overruns max_len "
                             f"{self.max_len} (prompt length {len(prompt)})")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new}")
        if not req.temperature >= 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {req.temperature}")
        if not req.top_p >= 0:
            raise ValueError(f"top_p must be >= 0, got {req.top_p}")
        if req.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {req.top_k}")
        if req.energy_budget_uj is not None and not req.energy_budget_uj > 0:
            raise ValueError(f"energy_budget_uj must be > 0, "
                             f"got {req.energy_budget_uj}")
        if self.paged:
            # FIFO admission head-blocks: a request that cannot fit even an
            # empty pool would deadlock the queue, so refuse it up front
            if not self.kv.fits(S, req.max_new):
                raise ValueError(
                    f"request needs more KV blocks than the pool holds "
                    f"({self.kv.pool_g.num_blocks} x {self.block_size}"
                    + (" per shard)" if self.n_shards > 1 else ")"))
        return prompt

    def submit(self, req: GenRequest) -> int:
        """Enqueue a request; returns its rid. Admission happens in step().

        Raises ValueError on an invalid request (see :meth:`validate`) and
        :class:`RejectedError` when the engine was built with ``max_pending``
        and the FIFO is full (backpressure, not an error in the request)."""
        self.validate(req)
        return self.scheduler.submit(req)

    def step(self) -> List[GenResult]:
        """Admit queued requests into free slots (paged: against the
        free-block budget; with a controller, also against the rolling uJ
        bucket), then advance every active slot one step: a mixed
        prefill+decode chunk step while any slot is still streaming its
        prompt (chunked mode), a pure decode step otherwise.  Finally the
        control plane sheds any request that exhausted its energy budget.
        Returns requests finished this step."""
        finished = self._admit_pending()
        active = self.scheduler.active_slots()
        if active:
            if self.chunked and any(s.prefilling for _, s in active):
                finished += self._chunk_advance(active)
            else:
                finished += self._decode_advance(active)
        if self.controller is not None:
            for rid in self.controller.over_budget(self):
                res = self.cancel(rid, reason="energy_budget")
                if res is not None:
                    finished.append(res)
        return finished

    def _admit_pending(self) -> List[GenResult]:
        """FIFO admission into free slots: stops at the first request the
        block budget (paged) or the controller's uJ bucket cannot take —
        head-blocking keeps admission order deterministic."""
        finished = []
        while self.scheduler.pending:
            rid, req = self.scheduler.peek_pending()
            shard = self.scheduler.pick_shard(
                self._bucket_len(len(req.prompt)), req.max_new)
            if shard is None:
                break
            if self.controller is not None and \
                    not self.controller.may_admit(self):
                break
            self.scheduler.pop_pending()
            sid = self.scheduler.free_slot(shard)
            self._admit(sid, rid, req)
            done = self._maybe_retire(sid)
            if done is not None:
                finished.append(done)
        return finished

    def _decode_advance(self, active) -> List[GenResult]:
        """Advance every decode-phase slot one generated token in one jitted
        pure-decode step (SpeculativeEngine overrides this with a
        draft-k/verify-one round)."""
        finished = []
        B = self.batch_size
        tokens = np.zeros(B, np.int32)
        index = np.zeros(B, np.int32)
        act = np.zeros(B, bool)
        seeds = np.zeros(B, np.uint32)
        spos = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        topp = np.ones(B, np.float32)
        enc = np.zeros(B, np.int32)
        for i, s in active:
            tokens[i] = s.last_token
            index[i] = s.pos
            act[i] = True
            seeds[i] = np.uint32(s.req.seed)
            spos[i] = s.sample_pos
            temps[i] = s.req.temperature
            topk[i] = s.req.top_k
            topp[i] = s.req.top_p
            enc[i] = s.enc_len

        self.peak_concurrent = max(self.peak_concurrent, len(active))
        extra = ()
        kwargs = {}
        if self.paged:
            # append-on-decode: a slot crossing a block boundary draws one of
            # its reserved blocks before the step writes at pos
            for i, s in active:
                if self.scheduler.kv_ensure(i, s.pos):
                    self._tables_dev = None
            needs = [1] * self.n_shards
            for i, s in active:
                sh = self._shard_of(i)
                needs[sh] = max(needs[sh], 1 + s.pos)
            extra, kwargs = self._paged_tables(needs)
        step_seed = self.seed + self._steps + 1 if self.fresh_noise else self.seed
        next_tok, self.cache, eaux = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(index),
            jnp.asarray(act), jnp.uint32(step_seed), jnp.asarray(seeds),
            jnp.asarray(spos), jnp.asarray(temps), jnp.asarray(topk),
            jnp.asarray(topp), jnp.asarray(enc), *extra, **kwargs)
        share = self._book_step(eaux, active)
        next_tok = np.asarray(next_tok)
        for i, s in active:
            s.energy_pj += float(share[self._shard_of(i)])
            s.steps += 1
            s.pos += 1
            t = int(next_tok[i])
            s.last_token = t
            s.generated.append(t)
            self._emit(s.rid, t)
            self._register_decode_blocks(i, s)
            done = self._maybe_retire(i)
            if done is not None:
                finished.append(done)
        return finished

    def _chunk_advance(self, active) -> List[GenResult]:
        """One mixed prefill+decode step: prefill-phase slots consume up to
        `prefill_chunk` prompt tokens at their exact positions, decode-phase
        slots advance one generated token — all in one jitted call with a
        per-slot phase mask (`ntok`).  Energy is split e/B per row exactly
        like the pure decode step (every row flows the same C lanes through
        the crossbars); a prefill row's share accrues to its prefill energy."""
        B, C = self.batch_size, self.prefill_chunk
        tokens = np.zeros((B, C), np.int32)
        start = np.zeros(B, np.int32)
        ntok = np.ones(B, np.int32)
        act = np.zeros(B, bool)
        seeds = np.zeros(B, np.uint32)
        spos = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        topp = np.ones(B, np.float32)
        for i, s in active:
            act[i] = True
            seeds[i] = np.uint32(s.req.seed)
            spos[i] = s.sample_pos
            temps[i] = s.req.temperature
            topk[i] = s.req.top_k
            topp[i] = s.req.top_p
            start[i] = s.pos
            if s.prefilling:
                take = min(C, len(s.prompt) - s.pos)
                tokens[i, :take] = s.prompt[s.pos:s.pos + take]
                ntok[i] = take
            else:
                tokens[i, 0] = s.last_token
        self.peak_concurrent = max(self.peak_concurrent, len(active))

        extra = ()
        kwargs = {}
        if self.paged:
            for i, s in active:
                if not s.prefilling and self.scheduler.kv_ensure(i, s.pos):
                    self._tables_dev = None
            needs = [1] * self.n_shards
            for i, _ in active:
                sh = self._shard_of(i)
                needs[sh] = max(needs[sh], int(start[i] + ntok[i]))
            extra, kwargs = self._paged_tables(needs)
        step_seed = self.seed + self._steps + 1 if self.fresh_noise else self.seed
        next_tok, self.cache, eaux = self._chunk(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(ntok), jnp.asarray(act), jnp.uint32(step_seed),
            jnp.asarray(seeds), jnp.asarray(spos), jnp.asarray(temps),
            jnp.asarray(topk), jnp.asarray(topp), *extra, **kwargs)
        share = self._book_step(eaux, active)
        next_tok = np.asarray(next_tok)
        finished = []
        for i, s in active:
            if s.prefilling:
                s.prefill_energy_pj += float(share[self._shard_of(i)])
                s.pos += int(ntok[i])
                self.prefill_tokens_total += int(ntok[i])
                if self.paged and self.prefix_cache:
                    # full prompt blocks just written become shareable
                    self.kv.register_filled(i, s.pos)
                if not s.prefilling:        # final chunk: first sampled token
                    t = int(next_tok[i])
                    s.last_token = t
                    s.generated.append(t)
                    self._emit(s.rid, t)
            else:
                s.energy_pj += float(share[self._shard_of(i)])
                s.steps += 1
                s.pos += 1
                t = int(next_tok[i])
                s.last_token = t
                s.generated.append(t)
                self._emit(s.rid, t)
                self._register_decode_blocks(i, s)
            done = self._maybe_retire(i)
            if done is not None:
                finished.append(done)
        return finished

    def _paged_tables(self, needs):
        """Stage the width-clamped block tables on device for a step whose
        per-shard write frontiers are `needs` (length n_shards): zero any
        prefix-cache evictions first, clamp the logical view to the
        block-rounded bucket of the furthest live write position (masks,
        gathers, and the fused kernel walk view_len positions instead of
        max_len), and re-upload only when the tables or a bucket changed.

        Sharded: the jit-static view width is the *max* over the shards'
        buckets (one SPMD program, one static shape) — but each shard's
        table rows are clamped to its **own** bucket, entries past it
        resolving to the zero block.  A long request on one shard therefore
        never makes another shard gather real blocks past its own frontier;
        the per-shard buckets are observable as `shard_view_lens`.

        Returns (extra_args, kwargs) for the jitted step; shared by the pure
        decode and mixed chunk paths."""
        self._zero_evicted()
        buckets = tuple(view_bucket(n, self.block_size, self.max_len)
                        for n in needs)
        vlen = max(buckets)
        key = (vlen, buckets)
        if self._tables_dev is None or self._tables_dev[0] != key:
            tg, tl = self.kv.gather_tables()
            width = -(-vlen // self.block_size)
            tg = tg[:, :width].copy()
            if self.n_shards > 1:
                for sh, b in enumerate(buckets):
                    w = -(-b // self.block_size)
                    lo = sh * self.shard_size
                    tg[lo:lo + self.shard_size, w:] = self.kv.zero_block_g
            self._tables_dev = (key, jnp.asarray(tg), jnp.asarray(tl))
        self.view_len = vlen
        self.shard_view_lens = list(buckets)
        return self._tables_dev[1:], {"view_len": vlen}

    def _book_step(self, eaux, active) -> np.ndarray:
        """Book one jitted step's aux into the engine totals.  Returns the
        (n_shards,) per-active-slot energy shares: every row issues the same
        crossbar reads per step, so an active slot is billed its *shard's*
        energy over the shard's rows, e_s / (batch_size / n_shards)
        (occupancy-independent), and the idle rows' share accrues to the
        shard's slice of idle_energy_pj — shared by the pure decode and
        mixed chunk paths.  Unsharded engines are the n_shards == 1 case of
        the same arithmetic (e / batch_size, bit-identical to the historic
        scalar path)."""
        self._steps += 1
        kv = np.asarray(eaux["kv_reads"], np.float64).reshape(-1)
        e = np.asarray(eaux["energy_pj"], np.float64).reshape(-1)
        self.kv_reads_total += float(kv.sum())
        self.shard_kv_reads += kv
        self._book_corners(eaux["corners"])
        self.total_energy_pj += float(e.sum())
        self.shard_energy_pj += e
        n_act = np.zeros(self.n_shards, np.int64)
        for i, _ in active:
            n_act[self._shard_of(i)] += 1
        self.shard_occupancy += n_act
        share = e / self.shard_size
        idle_inc = share * (self.shard_size - n_act)
        self.shard_idle_energy_pj += idle_inc
        self.idle_energy_pj += float(idle_inc.sum())
        return share

    def _register_decode_blocks(self, slot_id: int, s: Slot) -> None:
        """Decode-block registration: when a decode step fills a block (the
        slot's write frontier crosses a block boundary), extend the slot's
        rolling-hash chain over its *written stream* — prompt ++ generated
        tokens — and register the filled block in the prefix registry.  An
        identical few-shot continuation (same prompt, same greedy
        continuation, longer max_new) then admits against the decode-written
        blocks with zero incremental prefill energy, exactly like a prompt
        prefix hit."""
        if not (self.paged and self.prefix_cache):
            return
        if s.pos % self.block_size:
            return
        # written positions are [0, pos): prompt, then every generated token
        # except the newest (sampled this step, written next step)
        stream = np.concatenate(
            [s.prompt, np.asarray(s.generated[:-1], np.int32)])
        self.kv.register_filled(slot_id, s.pos, stream=stream)

    def _zero_evicted(self):
        """Zero blocks the prefix cache evicted for reuse — their stale K/V
        must never be gatherable by the new owner (same hygiene as
        zero-on-retire for unregistered blocks)."""
        if not (self.paged and self.prefix_cache):
            return
        if self.n_shards > 1:
            self._zero_evicted_sharded()
            return
        evicted = self.kv.pool_g.pop_evicted()
        if not evicted:
            return
        for lo in range(0, len(evicted), self.kv.width_g):
            ids = self._pad_ids(evicted[lo:lo + self.kv.width_g],
                                self.kv.width_g, self.kv.zero_block_g + 1)
            empty_l = self._pad_ids([], self.kv.width_l,
                                    self.kv.zero_block_l + 1)
            self.cache = self._zero_retired(self.cache, jnp.asarray(ids),
                                            jnp.asarray(empty_l),
                                            jnp.int32(0))

    def _zero_evicted_sharded(self):
        """Sharded eviction hygiene: each shard zeroes its own evicted ids —
        one (n_shards, width) grid per round, sentinel rows for shards with
        nothing to zero (their scatters drop)."""
        per_shard = self.kv.pop_evicted_g()
        if not any(per_shard):
            return
        n, wg, wl = self.n_shards, self.kv.width_g, self.kv.width_l
        rounds = max(-(-len(ids) // wg) for ids in per_shard if ids)
        for r in range(rounds):
            ids_g = np.full((n, wg), self.kv.zero_block_g + 1, np.int32)
            for sh, ids in enumerate(per_shard):
                chunk = ids[r * wg:(r + 1) * wg]
                ids_g[sh, :len(chunk)] = chunk
            ids_l = np.full((n, wl), self.kv.zero_block_l + 1, np.int32)
            slot = np.full(n, self.shard_size, np.int32)   # OOB -> dropped
            self.cache = self._zero_retired(self.cache, jnp.asarray(ids_g),
                                            jnp.asarray(ids_l),
                                            jnp.asarray(slot))

    def _emit(self, rid: int, token: int) -> None:
        if self.on_token is not None:
            self.on_token(rid, token)

    def cancel(self, rid: int, reason: str = "cancelled") -> Optional[GenResult]:
        """Cancel request `rid` wherever it is: still queued (removed, empty
        result) or bound to a slot (retired immediately with its partial
        tokens).  The slot's paged blocks are freed through the same
        refcount/zero-on-retire hygiene as a natural retirement — shared
        prefix-cache blocks only lose one reference and stay hit-able — and
        the energy already billed to the request rides out on the result, so
        per-request + idle == total conservation holds with cancelled
        partials.  `reason` becomes ``done_reason`` ("cancelled"/"timeout").
        Returns None when `rid` is unknown or already finished."""
        if self.scheduler.remove_pending(rid) is not None:
            return GenResult(rid=rid, tokens=np.zeros(0, np.int32),
                             energy_pj=0.0, prefill_energy_pj=0.0, steps=0,
                             done_reason=reason)
        slot_id = self.scheduler.slot_of(rid)
        if slot_id is None:
            return None
        return self._retire(slot_id, reason)

    def drain(self, stall_limit: int = 8) -> List[GenResult]:
        """Run step() until queue and slots are empty.

        Forward-progress guard: an active slot advances its position every
        step (prefill chunk or decode token), so a step that changes nothing
        — no admission, no position advance, no retirement, queue length
        unchanged — means the engine can never retire anything again (e.g. a
        pending request whose block budget is held by a leaked owner).
        `stall_limit` identical steps raise RuntimeError with the stuck
        state instead of spinning forever."""
        out = []
        stalled, last = 0, None
        while self.scheduler.busy:
            out.extend(self.step())
            snap = (self.scheduler.pending, len(out),
                    tuple((i, s.pos) for i, s in
                          self.scheduler.active_slots()))
            if snap == last:
                stalled += 1
                if stalled >= stall_limit:
                    slots = [f"slot {i}: rid={s.rid} pos={s.pos} "
                             f"prefilling={s.prefilling} "
                             f"generated={len(s.generated)}"
                             for i, s in self.scheduler.active_slots()]
                    pool = ""
                    if self.paged:
                        pool = (f"; pool free={self.kv.pool_g.num_free}"
                                f"/{self.kv.pool_g.num_blocks} blocks")
                    raise RuntimeError(
                        f"drain() made no progress for {stalled} steps: "
                        f"{self.scheduler.pending} pending, "
                        f"{self.scheduler.num_active} active "
                        f"[{'; '.join(slots) or 'none'}]{pool}")
            else:
                stalled = 0
            last = snap
        return out

    # -- metrics -------------------------------------------------------------
    def reset_metrics(self):
        """Zero every accounting counter (energy ledgers, shard splits,
        kv-read/prefill totals, occupancy integrals, the noise clock and the
        concurrency high-water mark) without touching serving state.

        This is the between-phases reset the benches need after warmup: the
        compiled steps, pools and scheduler stay live, only the books open
        fresh.  Requires an idle engine — resetting mid-flight would break
        per-request + idle == total conservation for in-flight requests."""
        assert not self.scheduler.busy, "reset_metrics() requires an idle engine"
        self.total_energy_pj = 0.0
        self.idle_energy_pj = 0.0
        self.corner_energy_pj = {}
        self.shard_energy_pj[:] = 0.0
        self.shard_idle_energy_pj[:] = 0.0
        self.shard_corner_energy_pj = {}
        self.shard_kv_reads[:] = 0.0
        self.shard_occupancy[:] = 0
        self._steps = 0
        self.peak_concurrent = 0
        self.kv_reads_total = 0.0
        self.prefill_tokens_total = 0
        self.cached_prefix_tokens = 0

    def metrics(self) -> dict:
        """Plain-python snapshot of the accounting counters (JSON-safe)."""
        return {
            "total_energy_pj": float(self.total_energy_pj),
            "idle_energy_pj": float(self.idle_energy_pj),
            "corner_energy_pj": {k: float(v)
                                 for k, v in self.corner_energy_pj.items()},
            "shard_energy_pj": [float(v) for v in self.shard_energy_pj],
            "shard_idle_energy_pj": [float(v)
                                     for v in self.shard_idle_energy_pj],
            "shard_occupancy": [int(v) for v in self.shard_occupancy],
            "steps": int(self._steps),
            "peak_concurrent": int(self.peak_concurrent),
            "kv_reads_total": float(self.kv_reads_total),
            "prefill_tokens_total": int(self.prefill_tokens_total),
            "cached_prefix_tokens": int(self.cached_prefix_tokens),
        }

    def energy_conserved(self, results, rtol: float = 1e-6) -> bool:
        """Per-request billed + idle waste == engine total (the conservation
        invariant, including partial/shed/cancelled results)."""
        billed = float(sum(r.energy_pj for r in results))
        return bool(np.isclose(billed + self.idle_energy_pj,
                               self.total_energy_pj, rtol=rtol))

    # -- batch-mode wrapper --------------------------------------------------
    def generate(self, requests):
        """Submit `requests` together and drain. Returns (token arrays in
        submission order, EMT energy in pJ billed to these requests). Resets
        the noise clock so repeated calls are bit-identical."""
        assert not self.scheduler.busy, "generate() requires an idle engine"
        self._steps = 0
        rids = [self.submit(r) for r in requests]
        res = {r.rid: r for r in self.drain()}
        outs = [np.asarray(res[rid].tokens) for rid in rids]
        return outs, float(sum(res[rid].energy_pj for rid in rids))

    def serve(self, requests, stagger: int = 0) -> List[GenResult]:
        """Streaming driver: submit one request every `stagger` steps
        (0 = all upfront), then run to completion. Returns results in
        submission (rid) order."""
        results = []
        for r in requests:
            self.submit(r)
            for _ in range(max(stagger, 0)):
                results += self.step()
        results += self.drain()
        return sorted(results, key=lambda r: r.rid)

    # -- internals -----------------------------------------------------------
    def _admit(self, slot_id: int, rid: int, req: GenRequest):
        """Bind `req` to slot `slot_id`.

        Chunked mode (default for decoder-only attention stacks): allocate
        the slot's blocks (+ decode reservation) and place the slot in the
        prefill phase — the prompt streams into the cache chunk by chunk
        through the mixed step, directly into pool blocks, with no separate
        prefill call.  With the prefix cache on, admission first walks the
        prompt's rolling-hash chain: resident shared prefix blocks are
        refcount-shared (their prefill is skipped entirely — zero incremental
        energy/kv_reads) and a partially shared tail block is reused
        copy-on-write.

        Legacy mode (recurrent / enc-dec / mrope stacks): prefill `req` alone
        into a power-of-two bucket (batch 1, compiled once per bucket) and
        scatter the rows into the slot's cache region, sampling the first
        token from the prefill logits."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if self.chunked:
            pos = 0
            if self.paged:
                if self.prefix_cache:
                    res = self.kv.admit_prefix(slot_id, prompt, req.max_new)
                    assert res is not None, "admission raced the block budget"
                    self._tables_dev = None
                    self._zero_evicted()
                    if res["cow"] is not None:
                        src, dst = res["cow"]
                        if self.n_shards > 1:
                            sh = self._shard_of(slot_id)
                            sv = np.zeros(self.n_shards, np.int32)
                            dv = np.full(self.n_shards,
                                         self.kv.zero_block_g + 1, np.int32)
                            sv[sh], dv[sh] = src, dst
                            self.cache = self._pool_copy(
                                self.cache, jnp.asarray(sv), jnp.asarray(dv))
                        else:
                            self.cache = self._pool_copy(
                                self.cache, jnp.int32(src), jnp.int32(dst))
                    pos = res["cached_len"]
                    self.cached_prefix_tokens += pos
                else:
                    ok = self.scheduler.kv_admit(slot_id, len(prompt),
                                                 req.max_new)
                    assert ok, "admission raced the block budget"
                    self._tables_dev = None
            self.scheduler.place(slot_id, Slot(rid=rid, req=req, pos=pos,
                                               last_token=0, prompt=prompt))
            return
        S = self._bucket_len(len(prompt))
        # bucket >= max_len: prefill at exact length (one extra compile for
        # the rare near-capacity prompt); left-pad into the bucket otherwise
        toks = np.zeros((1, S), np.int32)
        toks[0, S - len(prompt):] = prompt               # left-pad preserved
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.input_kind == "embeds":
            batch["embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.float32)
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.float32)
        small = lm.init_cache(self.cfg, 1, self.max_len)
        small, logits, aux = self._prefill(self.params, batch, small,
                                           jnp.uint32(self.seed))
        if self.paged:
            ok = self.scheduler.kv_admit(slot_id, S, req.max_new)
            assert ok, "admission raced the block budget"   # step() checked
            self._tables_dev = None
            row_g, row_l = self.kv.scatter_rows(slot_id)
            self.cache = self._insert(self.cache, small, jnp.asarray(row_g),
                                      jnp.asarray(row_l), jnp.int32(slot_id))
        else:
            self.cache = self._insert(self.cache, small, jnp.int32(slot_id))
        prefill_e = float(aux["energy_pj"])
        self._book_corners(aux["corners"])
        self.total_energy_pj += prefill_e
        tok0 = int(self._sample(
            logits, jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray([req.top_p], jnp.float32),
            jnp.asarray([req.seed], jnp.uint32),
            jnp.asarray([0], jnp.int32))[0])
        self.scheduler.place(slot_id, Slot(
            rid=rid, req=req, pos=S, last_token=tok0, generated=[tok0],
            prefill_energy_pj=prefill_e,
            enc_len=S if self.cfg.is_encdec else 0))
        self._emit(rid, tok0)

    def _maybe_retire(self, slot_id: int) -> Optional[GenResult]:
        s = self.scheduler.slots[slot_id]
        if not s.generated:
            return None                  # still streaming its prompt
        if s.req.eos_id is not None and s.generated[-1] == s.req.eos_id:
            reason = "eos"
        elif len(s.generated) >= s.req.max_new:
            reason = "max_new"
        elif s.pos >= self.max_len:
            reason = "max_len"           # cache exhausted: truncate
        else:
            return None
        return self._retire(slot_id, reason)

    def _retire(self, slot_id: int, reason: str) -> GenResult:
        """Release slot `slot_id` with ``done_reason=reason``: free its paged
        blocks (refcount-aware) or contiguous region, zero whatever became
        blank, and return the request's result — shared by natural
        retirement (_maybe_retire) and cancellation/timeout (cancel())."""
        slot = self.scheduler.retire(slot_id)
        # zero the retiring request's cache before its region/blocks can be
        # backfilled — stale K/V must never be gatherable by a later request
        if self.paged:
            freed_g, freed_l = self.scheduler.kv_release(slot_id)
            self._tables_dev = None
            if self.n_shards > 1:
                # one (n_shards, W) grid: the retiring slot's shard row holds
                # its freed local ids + local slot index, every other shard's
                # row is all sentinels (scatters drop)
                sh = self._shard_of(slot_id)
                n = self.n_shards
                ids_g = np.full((n, self.kv.width_g),
                                self.kv.zero_block_g + 1, np.int32)
                ids_g[sh, :len(freed_g)] = freed_g
                ids_l = np.full((n, self.kv.width_l),
                                self.kv.zero_block_l + 1, np.int32)
                ids_l[sh, :len(freed_l)] = freed_l
                slot_v = np.full(n, self.shard_size, np.int32)
                slot_v[sh] = slot_id - sh * self.shard_size
                self.cache = self._zero_retired(
                    self.cache, jnp.asarray(ids_g), jnp.asarray(ids_l),
                    jnp.asarray(slot_v))
            else:
                ids_g = self._pad_ids(freed_g, self.kv.width_g,
                                      self.kv.zero_block_g + 1)
                ids_l = self._pad_ids(freed_l, self.kv.width_l,
                                      self.kv.zero_block_l + 1)
                self.cache = self._zero_retired(self.cache,
                                                jnp.asarray(ids_g),
                                                jnp.asarray(ids_l),
                                                jnp.int32(slot_id))
        elif self.n_shards > 1:
            sh = self._shard_of(slot_id)
            slot_v = np.full(self.n_shards, self.shard_size, np.int32)
            slot_v[sh] = slot_id - sh * self.shard_size
            self.cache = self._zero_retired(self.cache, jnp.asarray(slot_v))
        else:
            self.cache = self._zero_retired(self.cache, jnp.int32(slot_id))
        return GenResult(
            rid=slot.rid, tokens=np.asarray(slot.generated, np.int32),
            energy_pj=slot.prefill_energy_pj + slot.energy_pj,
            prefill_energy_pj=slot.prefill_energy_pj, steps=slot.steps,
            done_reason=reason, draft_energy_pj=slot.draft_energy_pj,
            spec_proposed=slot.spec_proposed,
            spec_accepted=slot.spec_accepted)
