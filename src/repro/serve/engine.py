"""Serving runtime: sharded prefill/decode steps + a batched generation engine.

``serve_step`` (decode) is THE artifact the decode_32k / long_500k dry-run cells
lower: one new token against a seq_len KV cache, with all projections running as
EMT analog (optionally bit-serial, technique C) crossbar reads.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.nn.param import abstract_params, param_shardings
from repro.parallel.sharding import (RULES, make_shard_fn, batch_shardings,
                                     cache_shardings)


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def prefill_step(params, batch, cache, seed):
        ctx = Ctx(seed=seed, shard=shard)
        return lm.prefill(params, batch, cfg, ctx, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def decode_step(params, cache, tokens, index, seed):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(params, cache, tokens, index, cfg, ctx)
        return logits, cache, aux["energy_pj"]

    return decode_step


def serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                    rules_name: str = "serve_2d"):
    """(param_shardings, cache_shardings, cache_specs) for the serving mesh."""
    rules = RULES[rules_name]
    pspecs = lm.specs(cfg)
    psh = param_shardings(pspecs, mesh, rules)
    cspecs = lm.init_cache_specs(cfg, batch, max_len)
    csh = cache_shardings(cspecs, mesh, rules)
    return psh, csh, cspecs, rules


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    temperature: float = 0.0


class ServingEngine:
    """Minimal batched engine: pads requests to a fixed batch, prefills once,
    then decodes greedily step by step (single host; the sharded steps are the
    same functions the multi-pod dry-run compiles)."""

    def __init__(self, cfg: ModelConfig, params, batch_size: int, max_len: int,
                 mesh: Optional[Mesh] = None, rules=None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self._prefill = jax.jit(make_prefill_step(cfg, mesh, rules))
        self._decode = jax.jit(make_decode_step(cfg, mesh, rules),
                               donate_argnums=(1,))

    def generate(self, requests):
        assert len(requests) <= self.batch_size
        B = self.batch_size
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        cache = lm.init_cache(self.cfg, B, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.input_kind == "embeds":
            batch["embeds"] = jnp.zeros((B, S, self.cfg.d_model), jnp.float32)
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros((B, S, self.cfg.d_model), jnp.float32)
        cache, logits, _ = self._prefill(self.params, batch, cache,
                                         jnp.uint32(self.seed))
        max_new = max(r.max_new for r in requests)
        out = [[] for _ in range(B)]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        energy = 0.0
        for t in range(max_new):
            for i in range(len(requests)):
                out[i].append(int(tok[i]))
            logits, cache, e = self._decode(self.params, cache, tok, S + t,
                                            jnp.uint32(self.seed + t + 1))
            energy += float(e)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return [np.asarray(o) for o in out[:len(requests)]], energy
