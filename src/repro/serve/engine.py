"""Serving runtime: sharded prefill/decode steps + a continuous-batching engine.

``serve_step`` (decode) is THE artifact the decode_32k / long_500k dry-run cells
lower: one new token against a seq_len KV cache, with all projections running as
EMT analog (optionally bit-serial, technique C) crossbar reads.

Architecture (continuous batching)
----------------------------------
The engine owns a fixed batch of ``batch_size`` **slots** over one shared KV
cache of shape ``(batch_size, max_len, ...)`` per attention layer.  Each slot
is free or bound to exactly one in-flight request:

* **admission** — a FIFO :class:`~repro.serve.scheduler.Scheduler` assigns the
  queue head to a free slot.  The request's prompt is left-padded into a
  power-of-two length bucket, prefilled alone (batch 1, compiled once per
  bucket), and the resulting cache/state rows are scattered into the slot's
  region of the shared cache.  Admission happens *mid-decode*: other slots keep
  decoding at their own positions and nothing recompiles, because the decode
  step's shapes are static in ``batch_size``.
* **decode** — one jitted step per token for the whole batch.
  :func:`repro.models.lm.decode_step` takes a per-slot ``(B,)`` position vector
  plus an active mask, so slots at different sequence positions share the step;
  retired/free slots flow through the matmuls but their cache rows are frozen.
* **sampling** — :mod:`repro.serve.sampling` draws each slot's next token from
  a pure hash of (request seed, generated-token counter): deterministic per
  request, independent of slot placement and co-tenants.
* **retirement** — a slot is released on EOS, ``max_new`` tokens, or cache
  exhaustion (``max_len``), and immediately becomes available for backfill.
* **energy** — the paper's per-step scalar ``energy_pj`` aux is attributed per
  request: prefill energy goes to the admitted request; each decode step's
  energy is split by read counts — every slot (active or idle) issues the same
  crossbar reads per step, so an active slot is billed ``e/batch_size`` and
  the idle rows' share accrues to ``idle_energy_pj`` (scheduler waste, not any
  request's).  Per-request numbers are therefore occupancy-independent, and
  ``sum(per-request) + idle_energy_pj == total_energy_pj`` by construction.

Weight-noise seeding (technique A): with ``fresh_noise=True`` (default) every
decode step folds the global step counter into the EMT fluctuation seed — the
physical RTN picture, matching the pre-continuous-batching engine.  With
``fresh_noise=False`` the fluctuation is frozen at the engine seed (static
programming-noise picture), which makes generation a pure function of the
request — the property the alone-vs-staggered equivalence tests exercise.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.nn.param import abstract_params, param_shardings
from repro.parallel.sharding import (RULES, make_shard_fn, batch_shardings,
                                     cache_shardings)
from repro.serve import sampling
from repro.serve.scheduler import Scheduler, Slot


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def prefill_step(params, batch, cache, seed):
        ctx = Ctx(seed=seed, shard=shard)
        return lm.prefill(params, batch, cfg, ctx, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    """Lockstep decode step (scalar position) — the dry-run lowering artifact."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def decode_step(params, cache, tokens, index, seed):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(params, cache, tokens, index, cfg, ctx)
        return logits, cache, aux["energy_pj"]

    return decode_step


def make_serve_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], rules=None):
    """Continuous-batching decode: per-slot positions/active mask + fused
    per-slot seeded sampling. Returns (next_tokens, new_cache, energy_pj)."""
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)

    def serve_decode_step(params, cache, tokens, index, active, seed,
                          sample_seeds, sample_pos, temps, top_k, top_p):
        ctx = Ctx(seed=seed, shard=shard)
        logits, cache, aux = lm.decode_step(params, cache, tokens, index, cfg,
                                            ctx, active=active)
        next_tok = sampling.sample_tokens(logits, temps, top_k, top_p,
                                          sample_seeds, sample_pos)
        return next_tok, cache, aux["energy_pj"]

    return serve_decode_step


def serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                    rules_name: str = "serve_2d"):
    """(param_shardings, cache_shardings, cache_specs) for the serving mesh."""
    rules = RULES[rules_name]
    pspecs = lm.specs(cfg)
    psh = param_shardings(pspecs, mesh, rules)
    cspecs = lm.init_cache_specs(cfg, batch, max_len)
    csh = cache_shardings(cspecs, mesh, rules)
    return psh, csh, cspecs, rules


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    temperature: float = 0.0         # 0 = greedy
    top_k: int = 0                   # 0 = disabled
    top_p: float = 1.0               # >=1 = disabled
    seed: int = 0                    # sampling seed (deterministic per request)
    eos_id: Optional[int] = None     # stop token (None = run to max_new)


@dataclasses.dataclass
class GenResult:
    rid: int                         # request id, submission order
    tokens: np.ndarray               # (n,) int32 generated tokens
    energy_pj: float                 # total EMT energy billed to this request
    prefill_energy_pj: float         # ... of which prefill
    steps: int                       # decode steps the request participated in
    done_reason: str                 # "eos" | "max_new" | "max_len"


def prefill_bucket(n: int, lo: int = 4) -> int:
    """Smallest power-of-two >= n (min `lo`) — prefill compile-cache buckets.

    Sizing note for callers: a request's prompt occupies ``prefill_bucket(len)``
    cache positions (left-padded), so an engine serving prompts of length L for
    ``max_new`` tokens wants ``max_len >= prefill_bucket(L) + max_new - 1``."""
    b = lo
    while b < n:
        b *= 2
    return b


class ServingEngine:
    """Slot-based continuous-batching engine (single host; the sharded steps
    are the same functions the multi-pod dry-run compiles).

    Streaming API: ``submit()`` enqueues a request and returns its rid,
    ``step()`` advances the whole batch one token (admitting queued requests
    into free slots first) and returns any finished :class:`GenResult`s,
    ``drain()`` steps until idle.  ``generate()`` is the batch-mode wrapper.
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int, max_len: int,
                 mesh: Optional[Mesh] = None, rules=None, seed: int = 0,
                 fresh_noise: bool = True):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self.fresh_noise = fresh_noise
        self._prefill = jax.jit(make_prefill_step(cfg, mesh, rules))
        self._decode = jax.jit(make_serve_decode_step(cfg, mesh, rules),
                               donate_argnums=(1,))
        self._insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        self._sample = jax.jit(sampling.sample_tokens)
        self.scheduler = Scheduler(batch_size)
        self.cache = lm.init_cache(cfg, batch_size, max_len)
        self.total_energy_pj = 0.0
        self.idle_energy_pj = 0.0    # decode energy of idle slots (waste)
        self._steps = 0              # global decode-step counter (noise clock)

    # -- jitted helpers ------------------------------------------------------
    @staticmethod
    def _insert_slot(big, small, slot):
        """Scatter a freshly prefilled batch-1 cache into slot `slot`."""
        return jax.tree.map(lambda b, s: b.at[slot].set(s[0].astype(b.dtype)),
                            big, small)

    # -- streaming API -------------------------------------------------------
    def submit(self, req: GenRequest) -> int:
        """Enqueue a request; returns its rid. Admission happens in step()."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        assert 1 <= len(prompt) <= self.max_len, \
            f"prompt length {len(prompt)} vs max_len {self.max_len}"
        assert req.max_new >= 1, f"max_new must be >= 1, got {req.max_new}"
        return self.scheduler.submit(req)

    def step(self) -> List[GenResult]:
        """Admit queued requests into free slots, then decode one token for
        every active slot. Returns requests finished this step."""
        finished = []
        while self.scheduler.pending:
            sid = self.scheduler.free_slot()
            if sid is None:
                break
            rid, req = self.scheduler.pop_pending()
            self._admit(sid, rid, req)
            done = self._maybe_retire(sid)
            if done is not None:
                finished.append(done)

        active = self.scheduler.active_slots()
        if not active:
            return finished

        B = self.batch_size
        tokens = np.zeros(B, np.int32)
        index = np.zeros(B, np.int32)
        act = np.zeros(B, bool)
        seeds = np.zeros(B, np.uint32)
        spos = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        topp = np.ones(B, np.float32)
        for i, s in active:
            tokens[i] = s.last_token
            index[i] = s.pos
            act[i] = True
            seeds[i] = np.uint32(s.req.seed)
            spos[i] = s.sample_pos
            temps[i] = s.req.temperature
            topk[i] = s.req.top_k
            topp[i] = s.req.top_p

        step_seed = self.seed + self._steps + 1 if self.fresh_noise else self.seed
        next_tok, self.cache, e = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(index),
            jnp.asarray(act), jnp.uint32(step_seed), jnp.asarray(seeds),
            jnp.asarray(spos), jnp.asarray(temps), jnp.asarray(topk),
            jnp.asarray(topp))
        self._steps += 1
        e = float(e)
        self.total_energy_pj += e
        # every row issues the same reads per step: bill e/B to each active
        # slot (occupancy-independent) and book the idle rows' share as waste
        share = e / B
        self.idle_energy_pj += share * (B - len(active))
        next_tok = np.asarray(next_tok)
        for i, s in active:
            s.energy_pj += share
            s.steps += 1
            s.pos += 1
            t = int(next_tok[i])
            s.last_token = t
            s.generated.append(t)
            done = self._maybe_retire(i)
            if done is not None:
                finished.append(done)
        return finished

    def drain(self) -> List[GenResult]:
        """Run step() until queue and slots are empty."""
        out = []
        while self.scheduler.busy:
            out.extend(self.step())
        return out

    # -- batch-mode wrapper --------------------------------------------------
    def generate(self, requests):
        """Submit `requests` together and drain. Returns (token arrays in
        submission order, EMT energy in pJ billed to these requests). Resets
        the noise clock so repeated calls are bit-identical."""
        assert not self.scheduler.busy, "generate() requires an idle engine"
        self._steps = 0
        rids = [self.submit(r) for r in requests]
        res = {r.rid: r for r in self.drain()}
        outs = [np.asarray(res[rid].tokens) for rid in rids]
        return outs, float(sum(res[rid].energy_pj for rid in rids))

    def serve(self, requests, stagger: int = 0) -> List[GenResult]:
        """Streaming driver: submit one request every `stagger` steps
        (0 = all upfront), then run to completion. Returns results in
        submission (rid) order."""
        results = []
        for r in requests:
            self.submit(r)
            for _ in range(max(stagger, 0)):
                results += self.step()
        results += self.drain()
        return sorted(results, key=lambda r: r.rid)

    # -- internals -----------------------------------------------------------
    def _admit(self, slot_id: int, rid: int, req: GenRequest):
        """Prefill `req` alone into slot `slot_id` (left-pad into a power-of-two
        bucket) and sample its first token from the prefill logits."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        S = prefill_bucket(len(prompt))
        if S >= self.max_len:
            # bucket would leave no decode room: prefill at exact length
            # (one extra compile for the rare near-capacity prompt)
            S = len(prompt)
        toks = np.zeros((1, S), np.int32)
        toks[0, S - len(prompt):] = prompt               # left-pad preserved
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.input_kind == "embeds":
            batch["embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.float32)
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.float32)
        small = lm.init_cache(self.cfg, 1, self.max_len)
        small, logits, aux = self._prefill(self.params, batch, small,
                                           jnp.uint32(self.seed))
        self.cache = self._insert(self.cache, small, jnp.int32(slot_id))
        prefill_e = float(aux["energy_pj"])
        self.total_energy_pj += prefill_e
        tok0 = int(self._sample(
            logits, jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray([req.top_p], jnp.float32),
            jnp.asarray([req.seed], jnp.uint32),
            jnp.asarray([0], jnp.int32))[0])
        self.scheduler.place(slot_id, Slot(
            rid=rid, req=req, pos=S, last_token=tok0, generated=[tok0],
            prefill_energy_pj=prefill_e))

    def _maybe_retire(self, slot_id: int) -> Optional[GenResult]:
        s = self.scheduler.slots[slot_id]
        if s.req.eos_id is not None and s.generated[-1] == s.req.eos_id:
            reason = "eos"
        elif len(s.generated) >= s.req.max_new:
            reason = "max_new"
        elif s.pos >= self.max_len:
            reason = "max_len"           # cache exhausted: truncate
        else:
            return None
        slot = self.scheduler.retire(slot_id)
        return GenResult(
            rid=slot.rid, tokens=np.asarray(slot.generated, np.int32),
            energy_pj=slot.prefill_energy_pj + slot.energy_pj,
            prefill_energy_pj=slot.prefill_energy_pj, steps=slot.steps,
            done_reason=reason)
