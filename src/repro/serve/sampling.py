"""Per-slot seeded sampling: temperature / top-k / top-p over a (B, V) logit row.

Randomness discipline mirrors the per-layer noise planes in
``repro.core.emt_linear``: every draw is a pure counter-hash of

    (request_seed, request_position, vocab_column)

via :mod:`repro.core.hashrng` — no stateful PRNG.  Consequences:

* **deterministic per request** — the tokens a request samples depend only on
  its own seed and how many tokens it has generated, never on which slot it
  landed in, what else is in the batch, or the engine's global step;
* **independent across slots** — two different request seeds index disjoint
  hash streams, so co-scheduled requests do not share randomness.

``temperature == 0`` rows short-circuit to argmax (greedy), making greedy
requests bit-identical to the pre-continuous-batching engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashrng

# distinct hash plane for sampling draws (layer noise planes are crc32-derived;
# a collision would be harmless anyway — the seed domains differ)
SAMPLING_PLANE = 0x5A3D17


def gumbel_noise(seeds, positions, vocab: int):
    """(B,)x(B,) request seeds/positions -> (B, vocab) Gumbel(0,1) samples."""
    rows = jnp.asarray(positions).astype(jnp.uint32)[:, None]
    cols = jnp.arange(vocab, dtype=jnp.uint32)[None, :]
    bits = hashrng.hash_counters(jnp.asarray(seeds).astype(jnp.uint32)[:, None],
                                 rows, cols, plane=SAMPLING_PLANE)
    # u in (0, 1) at 23-bit precision: float32 has a 24-bit mantissa, so
    # converting wider counters rounds the top values up to exactly 1.0 and
    # makes the Gumbel +inf (breaking top-k/top-p masks with NaN). 23 bits
    # leaves room for the half-offset (max = (2^23-1)+0.5, exactly
    # representable), keeping u strictly inside (0, 1).
    u = ((bits >> 9).astype(jnp.float32) + 0.5) * (1.0 / 8388608.0)
    return -jnp.log(-jnp.log(u))


def sample_tokens(logits, temperature, top_k, top_p, seeds, positions):
    """Sample one token per row. All args (B,)-shaped except logits (B, V).

    temperature: 0 -> greedy argmax; >0 -> softmax sampling at that temperature.
    top_k:       0 -> disabled; k>0 -> restrict to the k highest logits.
    top_p:       >=1 (or <=0) -> disabled; else nucleus sampling mass.
    seeds:       per-request sampling seed (uint32).
    positions:   per-request generated-token counter (drives the hash stream).
    """
    B, V = logits.shape
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    t = jnp.asarray(temperature, jnp.float32)

    def _sampled():
        scaled = lf / jnp.maximum(t, 1e-6)[:, None]

        # top-k: keep logits >= the k-th largest (ties keep extra members —
        # still deterministic)
        k = jnp.asarray(top_k, jnp.int32)
        k = jnp.where(k > 0, jnp.clip(k, 1, V), V)
        srt = jnp.sort(scaled, axis=-1)[:, ::-1]
        kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
        masked = jnp.where(scaled >= kth, scaled, -jnp.inf)

        # top-p (nucleus): smallest prefix of the sorted distribution with
        # cumulative mass >= p; `cum - sp < p` always keeps the top-1 token
        p = jnp.asarray(top_p, jnp.float32)
        p = jnp.where((p <= 0.0) | (p >= 1.0), 1.0, p)
        probs = jax.nn.softmax(masked, axis=-1)
        sp = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(sp, axis=-1)
        keep = (cum - sp) < p[:, None]
        pmin = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1, keepdims=True)
        masked = jnp.where(probs >= pmin, masked, -jnp.inf)

        sampled = jnp.argmax(masked + gumbel_noise(seeds, positions, V),
                             axis=-1).astype(jnp.int32)
        return jnp.where(t > 0.0, sampled, greedy)

    # all-greedy batches (the serving default) skip the two (B,V) sorts and
    # the full-vocab hash — at 256k vocab that is the decode hot path
    return jax.lax.cond(jnp.any(t > 0.0), _sampled, lambda: greedy)
