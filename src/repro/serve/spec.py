"""Declarative serving/benchmark specs — one config surface for every driver.

Before this module, ``launch/serve.py``, both serving examples, and the four
``benchmarks/bench_*.py`` drivers each rebuilt the same engine out of their
own argparse plumbing, and the bench scenarios were hand-written functions
with divergent knobs.  The spec family replaces that with three declarative
dataclasses, each JSON round-trippable (``to_dict``/``from_dict`` with hard
unknown-key rejection, so a stale matrix file fails loudly instead of
silently dropping a knob):

* :class:`ServeSpec` — *how* to serve: arch + EMT placement, engine shape
  (batch/max_len/paged KV), kernel dispatch, chunked prefill + prefix cache,
  speculation, control-plane budgets, sharding, streaming front-end bounds,
  and default sampling.  Validation lives here, in one place: every invalid
  combination the engines would reject deep inside construction (prefix
  cache on a sliding-window stack, speculation on shards, placement vs
  device conflicts) is a ``ValueError`` at spec build/validation time.
  ``build_config()`` resolves the :class:`~repro.models.config.ModelConfig`;
  ``build_engine()`` constructs the (possibly speculative, possibly
  controlled) engine.

* :class:`ScenarioSpec` — *what* to serve: a workload cell around a
  ``ServeSpec`` (arrival process, request count, prompt-length mix,
  shared-prefix ratio, decode budget) plus the axis coordinates the matrix
  expansion stamped on it.

* :class:`MatrixSpec` — a declarative scenario matrix: a base scenario, a
  dict of axes (dotted field paths or compound labelled toggles), identity
  axes (cells differing only along these must be token-identical), and
  extra standalone cells.  ``expand()`` yields the cartesian product as
  validated ``ScenarioSpec`` cells.

The executor that runs cells lives in ``benchmarks/matrix.py``; the Pareto
frontier reduction over cell metrics lives in ``repro.analysis.frontier``.
See docs/benchmarks.md for the file format and worked examples.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Optional, Tuple

MODES = ("ideal", "analog", "bitserial")
ARRIVALS = ("lockstep", "stagger", "poisson")

# mirror of repro.kernels.ops.PAGED_ATTN_IMPLS, kept import-light here (the
# kernels module pulls in pallas); consistency is pinned by a test
PAGED_ATTN_IMPLS = ("auto", "pallas", "interpret", "ref")


def _reject_unknown(cls, d: dict):
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"unknown {cls.__name__} keys {unknown}; "
                         f"known: {sorted(known)}")


def _err(cond: bool, msg: str):
    if cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """How to serve: engine/placement/kernel/speculation knobs + validation.

    Every serving driver (launcher, examples, benches, the matrix executor)
    builds its config and engine through this one dataclass; their CLI flags
    are thin aliases over these fields.
    """
    # -- model / placement ---------------------------------------------------
    arch: str = "gemma3-1b"
    mode: str = "analog"                 # ideal | analog | bitserial
    device: Optional[str] = None         # one registered corner for all layers
    placement: Optional[str] = None      # heterogeneous preset (overrides
    #                                      mode/device; configs.PLACEMENTS)
    smoke: bool = True
    all_global: bool = False             # coerce sliding-window layers to
    #                                      global attention (prefix cache /
    #                                      speculation need an all-global stack)
    a_per_row: bool = False              # per-row DAC activation scale
    #                                      (occupancy-independent analog quant)
    model_overrides: Optional[Dict[str, Any]] = None   # cfg.replace(**kw)
    # -- engine --------------------------------------------------------------
    batch_size: int = 4
    max_len: Optional[int] = None        # None: callers derive from workload
    seed: int = 0
    frozen_noise: bool = False           # freeze EMT fluctuation at the seed
    # -- KV memory -----------------------------------------------------------
    paged: bool = False
    block_size: int = 16
    num_blocks: Optional[int] = None
    num_ring_blocks: Optional[int] = None
    # -- kernels -------------------------------------------------------------
    fused_paged_attn: bool = True
    paged_attn_impl: str = "auto"
    # -- prefill / prefix cache ----------------------------------------------
    chunked_prefill: Optional[bool] = None
    prefill_chunk: int = 16
    prefix_cache: bool = False
    # -- speculation ---------------------------------------------------------
    draft_placement: Optional[str] = None
    spec_k: int = 4
    # -- control plane -------------------------------------------------------
    energy_budget_uj: Optional[float] = None   # per-request SLA
    step_budget_uj: Optional[float] = None     # rolling admission bucket
    # -- sharding ------------------------------------------------------------
    shards: int = 1
    # -- streaming front-end -------------------------------------------------
    max_pending: int = 16
    deadline_s: Optional[float] = None
    # -- default sampling (per-request; GenRequest kwargs) -------------------
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        _err(self.mode not in MODES,
             f"mode {self.mode!r} not in {MODES}")
        _err(self.paged_attn_impl not in PAGED_ATTN_IMPLS,
             f"paged_attn_impl {self.paged_attn_impl!r} not in "
             f"{PAGED_ATTN_IMPLS}")
        _err(self.placement is not None and self.device is not None,
             "placement and device are mutually exclusive (a placement "
             "names its corners per layer)")
        _err(self.batch_size < 1, f"batch_size {self.batch_size} < 1")
        _err(self.max_len is not None and self.max_len < 2,
             f"max_len {self.max_len} < 2")
        _err(self.block_size < 1, f"block_size {self.block_size} < 1")
        _err(self.prefill_chunk < 1, f"prefill_chunk {self.prefill_chunk} < 1")
        _err(self.spec_k < 1, f"spec_k {self.spec_k} < 1")
        _err(self.shards < 1, f"shards {self.shards} < 1")
        _err(self.batch_size % self.shards != 0,
             f"batch_size {self.batch_size} not divisible by shards "
             f"{self.shards}")
        _err(self.prefix_cache and not self.paged,
             "prefix_cache requires paged=True (refcounted block sharing "
             "needs the block-table pool)")
        _err(self.draft_placement is not None and self.shards > 1,
             "speculative decoding is single-device for now (the draft "
             "shadow cache and verify step are not sharded)")
        _err(self.draft_placement is not None and self.temperature > 0,
             "speculative decoding is greedy-only (temperature must be 0)")
        _err(self.prefix_cache and self.draft_placement is not None,
             "speculation does not compose with the prefix cache yet "
             "(ROADMAP item 3)")
        _err(self.max_pending < 1, f"max_pending {self.max_pending} < 1")
        _err(self.deadline_s is not None and self.deadline_s <= 0,
             f"deadline_s {self.deadline_s} must be positive")
        for name in ("energy_budget_uj", "step_budget_uj"):
            v = getattr(self, name)
            _err(v is not None and v <= 0, f"{name} {v} must be positive")
        _err(self.temperature < 0, f"temperature {self.temperature} < 0")
        _err(self.top_k < 0, f"top_k {self.top_k} < 0")
        _err(not (0.0 < self.top_p <= 1.0),
             f"top_p {self.top_p} not in (0, 1]")
        if self.model_overrides is not None:
            _err(not isinstance(self.model_overrides, dict)
                 or not all(isinstance(k, str) for k in self.model_overrides),
                 "model_overrides must be a {field: value} dict")

    # -- round-trip ----------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeSpec":
        _reject_unknown(cls, d)
        return cls(**d)

    def replace(self, **kw) -> "ServeSpec":
        return dataclasses.replace(self, **kw)

    # -- resolution ----------------------------------------------------------
    @property
    def emt_label(self) -> str:
        """Grouping label for frontier reports: the placement preset, the
        pinned corner, or the single-corner mode."""
        return self.placement or self.device or self.mode

    def validate(self) -> "ServeSpec":
        """Deep validation: resolve the model config so stack-dependent
        combinations (prefix cache / speculation on a sliding-window stack,
        unknown arch/placement/corner) are rejected too.  Returns self."""
        self.build_config()
        return self

    def build_config(self):
        """Resolve the :class:`ModelConfig` this spec serves."""
        import jax.numpy as jnp

        from repro.configs import ARCHS, PLACEMENTS, get_config

        _err(self.arch not in ARCHS,
             f"unknown arch {self.arch!r}; known: {sorted(ARCHS)}")
        if self.placement is not None:
            _err(self.placement not in PLACEMENTS,
                 f"unknown placement {self.placement!r}; known: "
                 f"{sorted(PLACEMENTS)}")
            cfg = get_config(self.arch, smoke=self.smoke,
                             placement=self.placement)
        else:
            if self.device is not None:
                from repro.core.device import get_device
                try:
                    get_device(self.device)
                except KeyError as e:
                    raise ValueError(f"unknown device corner "
                                     f"{self.device!r}") from e
            cfg = get_config(self.arch, emt_mode=self.mode, smoke=self.smoke,
                             device=self.device)
        cfg = cfg.replace(dtype=jnp.float32,
                          fused_paged_attn=self.fused_paged_attn,
                          paged_attn_impl=self.paged_attn_impl)
        has_ring = bool(cfg.sliding_window) and "local" in cfg.blocks()
        if self.all_global and has_ring:
            cfg = cfg.replace(layer_pattern=("attn",), sliding_window=0)
            has_ring = False
        _err(self.prefix_cache and has_ring,
             "prefix_cache requires an all-global attention stack (ring K/V "
             "is positional and cannot be shared) — set all_global=True or "
             "pick a stack without sliding windows")
        _err(self.draft_placement is not None and has_ring,
             "speculative decoding requires an all-global attention stack "
             "(rejected-draft writes would clobber ring K/V) — set "
             "all_global=True")
        if self.model_overrides:
            cfg = cfg.replace(**self.model_overrides)
        if self.a_per_row:
            cfg = cfg.replace(emt=_quant_per_row(cfg.emt))
        return cfg

    def engine_kwargs(self, *, max_len: Optional[int] = None) -> dict:
        """Constructor kwargs for :class:`ServingEngine` (sans cfg/params)."""
        max_len = self.max_len if max_len is None else max_len
        _err(max_len is None,
             "max_len unresolved: set ServeSpec.max_len or pass max_len= "
             "(scenario executors derive it from the workload)")
        return dict(
            batch_size=self.batch_size, max_len=max_len, seed=self.seed,
            fresh_noise=not self.frozen_noise, paged=self.paged,
            block_size=self.block_size, num_blocks=self.num_blocks,
            num_ring_blocks=self.num_ring_blocks,
            chunked_prefill=self.chunked_prefill,
            prefill_chunk=self.prefill_chunk, prefix_cache=self.prefix_cache,
            n_shards=self.shards)

    def request_kwargs(self) -> dict:
        """Per-request :class:`GenRequest` defaults this spec carries."""
        return dict(temperature=self.temperature, top_k=self.top_k,
                    top_p=self.top_p, eos_id=self.eos_id,
                    energy_budget_uj=self.energy_budget_uj)

    def build_controller(self):
        """The energy control plane, if any budget knob is set (else None)."""
        if self.step_budget_uj is None and self.energy_budget_uj is None:
            return None
        from repro.serve.control import EnergyBudgetController
        return EnergyBudgetController(step_budget_uj=self.step_budget_uj)

    def build_engine(self, cfg=None, params=None, *,
                     max_len: Optional[int] = None, on_token=None, mesh=None):
        """Construct the engine this spec describes.

        ``cfg``/``params`` default to ``build_config()`` and a fresh
        ``init_params(lm.specs(cfg), PRNGKey(0))`` — pass them in to share
        weights across engines (the benches' paired-run pattern).
        """
        import jax

        from repro.models import lm
        from repro.nn.param import init_params
        from repro.serve.engine import ServingEngine

        if cfg is None:
            cfg = self.build_config()
        if params is None:
            params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
        kw = self.engine_kwargs(max_len=max_len)
        kw.update(on_token=on_token, mesh=mesh,
                  controller=self.build_controller())
        if self.draft_placement is not None:
            from repro.serve.speculative import SpeculativeEngine
            return SpeculativeEngine(cfg, params,
                                     draft_placement=self.draft_placement,
                                     spec_k=self.spec_k, **kw)
        return ServingEngine(cfg, params, **kw)


def _quant_per_row(emt):
    """Switch every corner of an EMT surface to per-row DAC scales."""
    from repro.core.placement import DevicePlacement, LayerRule

    def one(e):
        return e.replace(quant=dataclasses.replace(e.quant, a_per_row=True))

    if isinstance(emt, DevicePlacement):
        return dataclasses.replace(
            emt,
            rules=tuple(LayerRule(r.pattern, one(r.emt)) for r in emt.rules),
            default=one(emt.default))
    return one(emt)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """What to serve: one workload cell around a :class:`ServeSpec`.

    ``coords`` carries the matrix axis coordinates the expansion stamped on
    the cell (``(("kv", "paged_fused"), ("shared", "0.5"))``) — reducers use
    them to group cells (token-identity groups, legacy section emission,
    frontier grouping) without re-parsing names.
    """
    name: str = "cell"
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)
    # -- arrival process -----------------------------------------------------
    arrival: str = "lockstep"        # lockstep | stagger | poisson
    stagger: int = 0                 # steps between submissions (stagger)
    rate_rps: float = 0.0            # open-loop Poisson rate (poisson)
    # -- request mix ---------------------------------------------------------
    n_requests: int = 8
    prompt_lo: int = 8               # uniform prompt-length mix [lo, hi]
    prompt_hi: int = 8
    shared_prefix_ratio: float = 0.0   # leading fraction of prompt_lo shared
    #                                    across all requests (system prompt)
    max_new: int = 8
    workload_seed: int = 0
    coords: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        _err(self.arrival not in ARRIVALS,
             f"arrival {self.arrival!r} not in {ARRIVALS}")
        _err(self.arrival == "poisson" and self.rate_rps <= 0,
             "poisson arrival needs rate_rps > 0")
        _err(self.arrival == "stagger" and self.stagger < 1,
             "stagger arrival needs stagger >= 1")
        _err(self.n_requests < 1, f"n_requests {self.n_requests} < 1")
        _err(not (1 <= self.prompt_lo <= self.prompt_hi),
             f"prompt mix [{self.prompt_lo}, {self.prompt_hi}] invalid")
        _err(not (0.0 <= self.shared_prefix_ratio < 1.0),
             f"shared_prefix_ratio {self.shared_prefix_ratio} not in [0, 1)")
        _err(self.max_new < 1, f"max_new {self.max_new} < 1")
        object.__setattr__(self, "coords",
                           tuple((str(a), str(v)) for a, v in self.coords))

    @property
    def header_len(self) -> int:
        """Tokens of the shared header every request starts with."""
        return int(round(self.shared_prefix_ratio * self.prompt_lo))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["serve"] = self.serve.to_dict()
        d["coords"] = [list(c) for c in self.coords]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        _reject_unknown(cls, d)
        d = dict(d)
        if "serve" in d and isinstance(d["serve"], dict):
            d["serve"] = ServeSpec.from_dict(d["serve"])
        if "coords" in d:
            d["coords"] = tuple(tuple(c) for c in d["coords"])
        return cls(**d)

    def replace(self, **kw) -> "ScenarioSpec":
        return dataclasses.replace(self, **kw)

    def coord(self, axis: str, default: str = "") -> str:
        return dict(self.coords).get(axis, default)

    def group_key(self, drop_axes=()) -> Tuple[Tuple[str, str], ...]:
        """Coordinates minus `drop_axes` — the identity-group key."""
        return tuple((a, v) for a, v in self.coords if a not in drop_axes)


def _axis_label(value) -> str:
    if isinstance(value, dict):
        return str(value["label"])
    if value is None:
        return "none"
    if isinstance(value, bool):
        return "on" if value else "off"
    return str(value)


def _apply_field(d: dict, dotted: str, value):
    """Set a dotted field path ('serve.paged') inside a nested spec dict."""
    parts = dotted.split(".")
    for p in parts[:-1]:
        node = d.get(p)
        if not isinstance(node, dict):
            raise ValueError(f"axis path {dotted!r}: {p!r} is not a nested "
                             f"spec field")
        d = node
    if parts[-1] not in d:
        raise ValueError(f"axis path {dotted!r}: unknown field {parts[-1]!r}")
    d[parts[-1]] = value


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """A declarative scenario matrix.

    ``axes`` maps an axis name to a list of values.  Two value forms:

    * plain value — the axis name is a dotted field path into
      :class:`ScenarioSpec` (``"serve.paged": [false, true]``,
      ``"shared_prefix_ratio": [0.0, 0.5]``);
    * compound toggle — ``{"label": "paged_fused", "set": {"serve.paged":
      true, "serve.fused_paged_attn": true}}`` under any axis name, for
      toggles that flip several fields at once.

    ``identity_axes`` names axes whose cells must stay token-identical:
    cells differing *only* along these axes ran the same workload through a
    different memory/kernel path, so at temperature 0 with frozen noise the
    executor asserts their tokens match (the paged-vs-contiguous property,
    generalized).  ``expand()`` returns the cartesian product plus
    ``extra_cells`` as validated :class:`ScenarioSpec`\\ s, coordinates
    stamped.
    """
    name: str = "matrix"
    base: ScenarioSpec = dataclasses.field(default_factory=ScenarioSpec)
    axes: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    identity_axes: Tuple[str, ...] = ()
    extra_cells: Tuple[ScenarioSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "axes", {k: tuple(v) for k, v in self.axes.items()})
        object.__setattr__(self, "identity_axes", tuple(self.identity_axes))
        object.__setattr__(self, "extra_cells", tuple(self.extra_cells))
        for ax in self.identity_axes:
            _err(ax not in self.axes,
                 f"identity axis {ax!r} is not an axis; "
                 f"axes: {sorted(self.axes)}")
        for ax, values in self.axes.items():
            _err(len(values) == 0, f"axis {ax!r} has no values")

    @property
    def n_cells(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return (n if self.axes else 0) + len(self.extra_cells)

    def expand(self):
        """Cartesian product of the axes over `base` + the extra cells."""
        cells = []
        names = list(self.axes)
        for combo in itertools.product(*(self.axes[a] for a in names)):
            d = self.base.to_dict()
            coords = []
            for axis, value in zip(names, combo):
                label = _axis_label(value)
                coords.append((axis, label))
                if isinstance(value, dict):
                    for dotted, v in value["set"].items():
                        _apply_field(d, dotted, v)
                else:
                    _apply_field(d, axis, value)
            d["coords"] = coords
            d["name"] = "/".join([self.base.name]
                                 + [f"{a}={v}" for a, v in coords])
            cells.append(ScenarioSpec.from_dict(d))
        cells.extend(self.extra_cells)
        seen = set()
        for c in cells:
            _err(c.name in seen, f"duplicate cell name {c.name!r}")
            seen.add(c.name)
        return cells

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "axes": {k: list(v) for k, v in self.axes.items()},
            "identity_axes": list(self.identity_axes),
            "extra_cells": [c.to_dict() for c in self.extra_cells],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MatrixSpec":
        _reject_unknown(cls, d)
        d = dict(d)
        if "base" in d and isinstance(d["base"], dict):
            d["base"] = ScenarioSpec.from_dict(d["base"])
        if "extra_cells" in d:
            d["extra_cells"] = tuple(
                ScenarioSpec.from_dict(c) if isinstance(c, dict) else c
                for c in d["extra_cells"])
        return cls(**d)
