"""Energy-aware control plane for the serving engine (host-side, pure
Python — no jax in this module).

The repo's energy accounting is exact — every jitted step's EMT energy is
split across the active slots and the conservation invariant *per-request +
idle == total* holds to float tolerance (see docs/serving.md) — but until
this module nothing *acted* on it.  The control plane turns the meter into
policy, at two scopes:

* **Per-request SLA** (:attr:`~repro.serve.engine.GenRequest.energy_budget_uj`):
  a request may carry a hard uJ budget.  After every engine step the
  controller compares the energy billed to each active slot (prefill +
  decode + draft share) against its budget and sheds exhausted requests
  through the normal cancel/retire path with ``done_reason="energy_budget"``
  — the slot's partial tokens and billed energy ride out on the result, so
  conservation keeps holding with shed partials.  The shed is *post-hoc*:
  the step that crossed the budget is still billed (the energy was already
  spent in the crossbars); the SLA bounds the overrun to one step's share.

* **Per-engine admission** (rolling uJ bucket): the engine earns
  ``step_budget_uj`` of credit per jitted step (the step *is* the engine's
  clock — idle engines spend nothing) up to a ``burst_uj`` cap, and every
  step's booked energy (all slots + idle share, both placements of a
  speculative engine) is debited.  While the bucket is overdrawn, admission
  of *new* requests head-blocks in the FIFO exactly like the paged
  free-block budget; already-admitted requests are never shed by the bucket
  (shedding work whose energy is already spent saves nothing).  One
  deliberate exception prevents deadlock and wasted idle power: an engine
  with **no active slots** always admits — deferring the only runnable
  request would stall the clock that refills the bucket.

One controller instance serves one engine (it tracks the engine's step/energy
counters by delta).  Wire it up via ``ServingEngine(..., controller=...)``;
the streaming front-end needs no changes — shed requests surface exactly
like cancellations, with their own ``done_reason``.  See
docs/control_plane.md for the policy discussion.
"""
from __future__ import annotations

from typing import List, Optional


class EnergyBudgetController:
    """uJ-budget admission gate + per-request energy-SLA shedding."""

    def __init__(self, step_budget_uj: Optional[float] = None,
                 burst_uj: Optional[float] = None):
        if step_budget_uj is not None and not step_budget_uj > 0:
            raise ValueError(f"step_budget_uj must be > 0, "
                             f"got {step_budget_uj}")
        self.step_budget_uj = step_budget_uj
        # default burst: 16 steps of credit — enough to absorb a prefill
        # burst without letting the engine run unboundedly hot
        if burst_uj is None and step_budget_uj is not None:
            burst_uj = 16.0 * step_budget_uj
        self.burst_uj = burst_uj
        # the bucket starts full: a fresh engine may spend its burst
        self.balance_uj = burst_uj if burst_uj is not None else 0.0
        self._seen_steps = 0
        self._seen_energy_pj = 0.0
        # observability counters (read by benches/tests/the launch report)
        self.shed = 0                # requests shed on their own budget
        self.deferred_steps = 0      # admission attempts deferred by the bucket

    # -- bucket bookkeeping --------------------------------------------------
    def _sync(self, engine) -> None:
        """Fold the engine's progress since the last look into the bucket:
        credit per new jitted step, debit the energy booked meanwhile."""
        if self.step_budget_uj is None:
            return
        dsteps = engine._steps - self._seen_steps
        de_pj = engine.total_energy_pj - self._seen_energy_pj
        self._seen_steps = engine._steps
        self._seen_energy_pj = engine.total_energy_pj
        self.balance_uj = min(
            self.burst_uj,
            self.balance_uj + dsteps * self.step_budget_uj) - de_pj * 1e-6

    # -- engine hooks --------------------------------------------------------
    def may_admit(self, engine) -> bool:
        """Admission gate, called per queued request from the engine's FIFO
        admission loop.  False head-blocks the queue this step."""
        if self.step_budget_uj is None:
            return True
        self._sync(engine)
        if engine.scheduler.num_active == 0:
            return True              # idle engine: never deadlock the clock
        if self.balance_uj <= 0.0:
            self.deferred_steps += 1
            return False
        return True

    def over_budget(self, engine) -> List[int]:
        """Rids of active requests whose billed energy exceeded their own
        energy_budget_uj — the engine cancels them with
        ``done_reason="energy_budget"`` after each step."""
        self._sync(engine)
        shed = []
        for _, s in engine.scheduler.active_slots():
            budget = getattr(s.req, "energy_budget_uj", None)
            if budget is None:
                continue
            billed_uj = (s.prefill_energy_pj + s.energy_pj) * 1e-6
            if billed_uj >= budget:
                shed.append(s.rid)
        self.shed += len(shed)
        return shed
