from repro.train.optimizer import (Optimizer, OptimizerConfig, cosine_schedule,
                                   constant_schedule, clip_by_global_norm)
from repro.train.step import (TrainConfig, make_train_step, jit_train_step,
                              init_state, make_state_shardings)
from repro.train.loop import LoopConfig, train_loop
