"""Fault-tolerant training loop.

* auto-resume from the latest valid checkpoint (elastic across meshes),
* periodic + SIGTERM-triggered (preemption) checkpointing, async by default,
* straggler watchdog: per-step wall-time EWMA; steps slower than
  ``straggler_factor x`` EWMA are logged through a pluggable hook (at fleet scale
  the hook feeds the scheduler's replace-node policy; here it logs),
* metrics streamed to JSONL for the benchmark harness.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 200
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    straggler_factor: float = 3.0


def train_loop(state, train_step: Callable, data_iter_at: Callable[[int], dict],
               cfg: LoopConfig, *, state_shardings=None,
               straggler_hook: Callable = None, log=print):
    """Run to cfg.total_steps with checkpoint/restart and watchdog.

    data_iter_at(step) must return the batch for that step (deterministic
    pipelines make restarts exact).  Returns (state, history list of metrics).
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
    restored, meta = mgr.restore_latest(state, state_shardings)
    if restored is not None:
        state = restored
        log(f"[loop] resumed from step {meta['step']}")

    stop = {"flag": False}

    def on_term(signum, frame):
        stop["flag"] = True
        log("[loop] SIGTERM — checkpointing and exiting")
    old = signal.signal(signal.SIGTERM, on_term)

    history = []
    ewma = None
    try:
        step = int(jax.device_get(state["step"]))
        while step < cfg.total_steps and not stop["flag"]:
            batch = data_iter_at(step)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            jax.block_until_ready(state["step"])
            dt = time.time() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > cfg.straggler_factor * ewma and step > 5:
                msg = f"[watchdog] step {step} took {dt:.3f}s (ewma {ewma:.3f}s)"
                (straggler_hook or log)(msg)
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                m = {k: float(np.asarray(jax.device_get(v)))
                     for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dt
                history.append(m)
                log(f"[step {step:5d}] " + " ".join(
                    f"{k}={v:.4g}" for k, v in m.items() if k != "step"))
                if cfg.metrics_path:
                    with open(cfg.metrics_path, "a") as f:
                        f.write(json.dumps(m) + "\n")
            if step % cfg.ckpt_every == 0 or stop["flag"] or \
                    step == cfg.total_steps:
                mgr.save(step, state)
        mgr.save(int(jax.device_get(state["step"])), state, block=True)
    finally:
        mgr.wait()
        signal.signal(signal.SIGTERM, old)
    return state, history
