"""Distributed train/serve step builders.

``make_train_step`` assembles loss -> grad -> clip -> (optionally pod-compressed
reduce) -> optimizer into one jittable function with full in/out shardings derived
from the parameter specs — the single artifact the dry-run lowers and the training
loop executes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.nn.param import abstract_params, param_shardings
from repro.parallel.sharding import make_shard_fn, batch_shardings, RULES
from repro.train.optimizer import Optimizer, OptimizerConfig, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lam: float = 1e-6                   # technique-B regularization weight
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    opt: OptimizerConfig = OptimizerConfig()


def make_state_specs(cfg: ModelConfig, opt: Optimizer):
    """Abstract (ShapeDtypeStruct) train state — dry-run input, no allocation."""
    pspecs = lm.specs(cfg)
    aparams = abstract_params(pspecs)
    astate = {
        "params": aparams,
        "opt": jax.eval_shape(opt.init, aparams),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return astate, pspecs


def make_state_shardings(cfg: ModelConfig, opt: Optimizer, mesh: Mesh, rules):
    astate, pspecs = make_state_specs(cfg, opt)
    psh = param_shardings(pspecs, mesh, rules)
    return {
        "params": psh,
        "opt": opt.shardings_from_abstract(astate["opt"], psh, mesh),
        "step": NamedSharding(mesh, P()),
    }, astate


def init_state(cfg: ModelConfig, opt: Optimizer, key):
    from repro.nn.param import init_params
    params = init_params(lm.specs(cfg), key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Optional[Mesh],
                    rules: Optional[dict] = None, *, schedule=None):
    """Returns train_step(state, batch) -> (state, metrics) (pure, jittable)."""
    opt = Optimizer(tcfg.opt)
    shard = make_shard_fn(mesh, rules) if mesh is not None else (lambda x, n: x)
    if schedule is None:
        from repro.train.optimizer import cosine_schedule
        schedule = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)

    def loss_fn(params, batch, step):
        ctx = Ctx(seed=step.astype(jnp.uint32), shard=shard)
        return lm.train_loss(params, batch, cfg, ctx, lam=tcfg.lam)

    def train_step(state, batch):
        step = state["step"]
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch, step)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = schedule(step)
        new_params, new_opt = opt.update(grads, state["opt"], state["params"],
                                         lr, step)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt": new_opt, "step": step + 1}, metrics

    return train_step, opt


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                   batch_specs: dict, rules_name: str = "train_fsdp_tp",
                   donate: bool = True):
    """Fully-sharded jitted step + the abstract state/batch specs (dry-run API)."""
    rules = RULES[rules_name]
    train_step, opt = make_train_step(cfg, tcfg, mesh, rules)
    state_sh, astate = make_state_shardings(cfg, opt, mesh, rules)
    batch_sh = batch_shardings(batch_specs, mesh, rules)
    jitted = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_sh, astate, opt
