"""Optimizers from scratch: SGD-momentum, AdamW, Adafactor.

Each optimizer exposes

    init(params)                      -> state (pytree of dicts mirroring params)
    update(grads, state, params, lr)  -> (new_params, new_state)
    state_shardings(param_shardings, param_specs) -> shardings for `state`

State trees mirror the parameter tree leaf-for-leaf (Adafactor leaves are dicts of
factored moments), so ZeRO-style sharding falls out of the parameter shardings.
Updates are computed in fp32 regardless of parameter dtype (bf16-safe).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | sgd | adafactor
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128


class Optimizer:
    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, params):
        c = self.cfg
        if c.name == "sgd":
            return jax.tree.map(
                lambda p: {"m": jnp.zeros(p.shape, jnp.float32)}, params)
        if c.name == "adamw":
            return jax.tree.map(
                lambda p: {"m": jnp.zeros(p.shape, jnp.float32),
                           "v": jnp.zeros(p.shape, jnp.float32)}, params)
        if c.name == "adafactor":
            def one(p):
                if p.ndim >= 2 and min(p.shape[-2:]) >= c.min_dim_factored:
                    return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                            jnp.float32)}
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return jax.tree.map(one, params)
        raise ValueError(c.name)

    # ---------------------------------------------------------------- update
    def update(self, grads, state, params, lr, step):
        c = self.cfg
        stepf = step.astype(jnp.float32) + 1.0

        def upd(path_g, s, p):
            g = path_g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            if c.name == "sgd":
                m = c.momentum * s["m"] + g
                delta = lr * m
                new_s = {"m": m}
            elif c.name == "adamw":
                m = c.b1 * s["m"] + (1 - c.b1) * g
                v = c.b2 * s["v"] + (1 - c.b2) * g * g
                mh = m / (1 - c.b1 ** stepf)
                vh = v / (1 - c.b2 ** stepf)
                delta = lr * (mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * pf)
                new_s = {"m": m, "v": v}
            else:  # adafactor (no momentum, factored second moment)
                beta2 = 1.0 - stepf ** (-c.decay_rate)
                g2 = g * g + 1e-30
                if "vr" in s:
                    vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                    vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                    r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                         1e-30)
                    precond = 1.0 / jnp.sqrt(
                        r[..., None] * vc[..., None, :] + 1e-30)
                    new_s = {"vr": vr, "vc": vc}
                else:
                    v = beta2 * s["v"] + (1 - beta2) * g2
                    precond = 1.0 / jnp.sqrt(v + 1e-30)
                    new_s = {"v": v}
                u = g * precond
                # update clipping (Adafactor RMS rule)
                rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
                u = u / jnp.maximum(1.0, rms)
                delta = lr * (u + c.weight_decay * pf)
            return (pf - delta).astype(p.dtype), new_s

        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_s = td.flatten_up_to(state)
        flat_p = td.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
        new_state = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
        return new_params, new_state

    # -------------------------------------------------------------- sharding
    def shardings_from_abstract(self, abstract_state, param_shardings, mesh):
        """Build state shardings by matching each state leaf against its param's
        sharding: same-rank leaves reuse it; factored leaves drop the removed dim."""
        def one(psh, sdict, aval_dict):
            out = {}
            spec = list(psh.spec) if psh is not None else []
            for k, aval in aval_dict.items():
                rank = len(aval.shape)
                if k in ("m", "v") and rank == len(spec):
                    out[k] = psh
                elif k == "vr":   # param.shape[:-1]
                    out[k] = NamedSharding(mesh, P(*spec[:-1])) if spec else \
                        NamedSharding(mesh, P())
                elif k == "vc":   # param.shape[:-2] + [-1]
                    s = tuple(spec[:-2]) + tuple(spec[-1:]) if len(spec) >= 2 \
                        else tuple(spec)
                    out[k] = NamedSharding(mesh, P(*s))
                else:
                    out[k] = NamedSharding(mesh, P(*([None] * rank)))
            return out

        flat_p, td = jax.tree_util.tree_flatten(param_shardings)
        flat_a = td.flatten_up_to(abstract_state)
        out = [one(p, None, a) for p, a in zip(flat_p, flat_a)]
        return jax.tree_util.tree_unflatten(td, out)


# ---------------------------------------------------------------- schedules
def cosine_schedule(base_lr, warmup: int, total: int, min_ratio=0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, (s + 1.0) / max(1, warmup))
        t = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, base_lr * cos)
    return lr


def constant_schedule(base_lr):
    return lambda step: jnp.float32(base_lr)
