"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE with early fusion (stub).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        experts_per_token=1,
        moe_d_ff=8192,
        moe_every=1,
        rope_theta=5.0e5,
        input_kind="embeds",            # early-fusion multimodal stub
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
