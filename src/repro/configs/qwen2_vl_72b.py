"""qwen2-vl-72b [vlm] — M-RoPE, dynamic-resolution vision frontend (stubbed).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064  [arXiv:2409.12191]
The vision tower is a STUB: `input_specs()` feeds precomputed patch embeddings
(B, S, D); M-RoPE runs over (t, h, w) position-id streams.
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        rope_type="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1.0e6,
        input_kind="embeds",
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
