"""Config helpers: EMT presets and smoke-scale reduction."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.emt_linear import EMTConfig, IDEAL
from repro.core.quant import QuantConfig
from repro.core.noise import NoiseConfig
from repro.models.config import ModelConfig


def emt_preset(mode: str = "analog", rng: str = "hash",
               intensity: str = "normal", rho_init: float = 4.0,
               energy_accounting: str = "full",
               store_int8: bool = False) -> EMTConfig:
    """Standard EMT configuration used by training/serving/dry-run."""
    if mode == "ideal":
        return IDEAL
    from repro.core.device import DeviceModel
    return EMTConfig(
        mode=mode,
        quant=QuantConfig(w_bits=8, a_bits=8, enabled=True),
        noise=NoiseConfig(backend=rng, granularity="per_step"),
        device=DeviceModel(intensity=intensity),
        rho_init=rho_init,
        energy_accounting=energy_accounting,
        store_int8=store_int8,
    )


def shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family smoke config: tiny widths, few layers/experts, CPU fp32.

    Keeps the structural signature (pattern, GQA ratio, MoE top-k, enc-dec,
    softcaps, rope flavor) so smoke tests exercise the same code paths.
    """
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(cfg.num_kv_heads, heads))
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads                                   # preserve MHA
    elif cfg.num_kv_heads == 1:
        kv = 1                                       # preserve MQA (gemma3)
    pattern = cfg.layer_pattern
    layers = min(cfg.num_layers, max(2, len(pattern)))
    kw = dict(
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token,
                              min(cfg.num_experts, 4)) if cfg.num_experts else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        sliding_window=8 if cfg.sliding_window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        dtype=jnp.float32,
        mrope_sections=(2, 3, 3) if cfg.rope_type == "mrope" else
        cfg.mrope_sections,
    )
    kw.update(overrides)
    return cfg.replace(name=cfg.name + "-smoke", **kw)
