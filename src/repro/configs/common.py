"""Config helpers: EMT presets, device placements, smoke-scale reduction."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.emt_linear import EMTConfig, IDEAL
from repro.core.placement import DevicePlacement, LayerRule, emt_for_corner
from repro.core.quant import QuantConfig
from repro.core.noise import NoiseConfig
from repro.models.config import ModelConfig


def emt_preset(mode: str = "analog", rng: str = "hash",
               intensity: str = "normal", rho_init: float = 4.0,
               energy_accounting: str = "full",
               store_int8: bool = False,
               device: str | None = None) -> EMTConfig:
    """Standard EMT configuration used by training/serving/dry-run.

    `device` names a registered technology corner (core/device.py registry);
    None keeps the paper's default (PCM-like) cell.
    """
    if mode == "ideal":
        return IDEAL
    from repro.core.device import DeviceModel, get_device
    dev = get_device(device) if device else DeviceModel()
    return EMTConfig(
        mode=mode,
        quant=QuantConfig(w_bits=8, a_bits=8, enabled=True),
        noise=NoiseConfig(backend=rng, granularity="per_step"),
        device=dev.with_intensity(intensity),
        rho_init=rho_init,
        energy_accounting=energy_accounting,
        store_int8=store_int8,
        corner=device or "",
    )


def mixed_placement(rng: str = "hash") -> DevicePlacement:
    """The worked mixed-technology example (docs/device_models.md): analog
    attention on PCM, bit-serial MLPs/experts on RRAM, routers on digital
    SRAM, everything else (SSM/xLSTM projections, unembed) analog PCM."""
    noise = NoiseConfig(backend=rng, granularity="per_step")
    pcm = emt_for_corner("pcm", "analog").replace(noise=noise)
    rram_bs = emt_for_corner("rram", "bitserial").replace(noise=noise)
    sram = emt_for_corner("sram_digital", "analog").replace(noise=noise)
    return DevicePlacement(
        rules=(
            LayerRule("*/attn/*", pcm),
            LayerRule("*/xattn/*", pcm),
            LayerRule("*/mlp/*", rram_bs),
            LayerRule("*/moe/experts", rram_bs),
            LayerRule("*/moe/router", sram),
        ),
        default=pcm)


def placement_preset(name: str, rng: str = "hash") -> DevicePlacement:
    """Named placement presets for --placement flags."""
    noise = NoiseConfig(backend=rng, granularity="per_step")
    if name == "mixed":
        return mixed_placement(rng)
    if name == "attn-pcm":
        # fragile everything-else digital, attention analog (Joshi-style
        # analog/digital split)
        return DevicePlacement(
            rules=(LayerRule("*/attn/*",
                             emt_for_corner("pcm", "analog").replace(noise=noise)),),
            default=IDEAL)
    if name == "digital-router":
        # one global analog config, routers pinned to the digital corner
        return DevicePlacement(
            rules=(LayerRule("*/moe/router",
                             emt_for_corner("sram_digital", "analog")
                             .replace(noise=noise)),),
            default=emt_preset("analog", rng=rng))
    raise KeyError(f"unknown placement preset {name!r}; "
                   f"known: {sorted(PLACEMENTS)}")


PLACEMENTS = ("mixed", "attn-pcm", "digital-router")


def shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family smoke config: tiny widths, few layers/experts, CPU fp32.

    Keeps the structural signature (pattern, GQA ratio, MoE top-k, enc-dec,
    softcaps, rope flavor) so smoke tests exercise the same code paths.
    """
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(cfg.num_kv_heads, heads))
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads                                   # preserve MHA
    elif cfg.num_kv_heads == 1:
        kv = 1                                       # preserve MQA (gemma3)
    pattern = cfg.layer_pattern
    layers = min(cfg.num_layers, max(2, len(pattern)))
    kw = dict(
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token,
                              min(cfg.num_experts, 4)) if cfg.num_experts else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        sliding_window=8 if cfg.sliding_window else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        dtype=jnp.float32,
        mrope_sections=(2, 3, 3) if cfg.rope_type == "mrope" else
        cfg.mrope_sections,
    )
    kw.update(overrides)
    return cfg.replace(name=cfg.name + "-smoke", **kw)
