"""llama3-405b [dense] — GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256  [arXiv:2407.21783]
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5.0e5,
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt), num_layers=3)
