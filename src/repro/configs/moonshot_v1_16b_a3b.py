"""moonshot-v1-16b-a3b [moe] — Moonlight-style fine-grained MoE, 64e top-6.

48L d_model=2048 16H (MHA kv=16) d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        num_experts=64,
        experts_per_token=6,
        moe_d_ff=1408,
        moe_every=1,
        rope_theta=5.0e4,
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
