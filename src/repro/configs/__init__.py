"""Architecture registry: the 10 assigned architectures + the paper's own CNNs.

    from repro.configs import get_config, ARCHS
    cfg = get_config("llama3-405b", emt_mode="analog")
    cfg = get_config("llama3-405b", smoke=True)
"""
from __future__ import annotations

from repro.configs.common import (emt_preset, shrink, placement_preset,
                                  mixed_placement, PLACEMENTS)
from repro.configs import (jamba_v0_1_52b, qwen2_vl_72b, moonshot_v1_16b_a3b,
                           llama4_scout_17b_a16e, xlstm_350m, deepseek_67b,
                           gemma3_1b, llama3_405b, gemma2_9b,
                           seamless_m4t_medium, paper_cnn)

ARCHS = {
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "xlstm-350m": xlstm_350m,
    "deepseek-67b": deepseek_67b,
    "gemma3-1b": gemma3_1b,
    "llama3-405b": llama3_405b,
    "gemma2-9b": gemma2_9b,
    "seamless-m4t-medium": seamless_m4t_medium,
}

# shapes each arch runs (assignment rules; see DESIGN.md §5):
# long_500k only for SSM/hybrid archs; all archs here have decoders.
LONG_CONTEXT_ARCHS = ("jamba-v0.1-52b", "xlstm-350m")


def arch_shapes(name: str):
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes


def get_config(name: str, *, emt_mode: str = None, rng: str = "hash",
               intensity: str = None, smoke: bool = False,
               placement=None, **emt_kw):
    """`placement` (DevicePlacement, EMTConfig, or preset name from
    configs.common.PLACEMENTS) replaces the single-corner emt_* preset —
    passing any explicit emt knob alongside it is an error, not a silent
    override. Without a placement, emt_mode/intensity default to
    "analog"/"normal"."""
    mod = ARCHS[name]
    if placement is not None:
        # a placement fully specifies mode/device/intensity per layer — don't
        # silently drop conflicting single-corner knobs
        knobs = dict(emt_mode=emt_mode, intensity=intensity, **emt_kw)
        conflict = sorted(k for k, v in knobs.items() if v is not None)
        if conflict:
            raise ValueError(f"placement= overrides per-corner EMT settings; "
                             f"drop {conflict}")
        emt = placement_preset(placement, rng=rng) \
            if isinstance(placement, str) else placement
    else:
        emt = emt_preset(emt_mode or "analog", rng=rng,
                         intensity=intensity or "normal", **emt_kw)
    return mod.smoke(emt) if smoke else mod.build(emt)
