"""deepseek-67b [dense] — llama-architecture dense model.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400  [arXiv:2401.02954]
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        rope_theta=1.0e4,
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt), num_layers=3)
