"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]).

24L d_model=1024 4H d_ff=0 vocab=50304  [arXiv:2405.04517]
Blocks carry their own projections (d_ff=0: no separate FFN).
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        layer_pattern=("mlstm",) * 7 + ("slstm",),     # 7:1
        tie_embeddings=True,
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt), num_layers=4,
                  layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"))
