"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone.

12L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206  [arXiv:2308.11596]
The speech frontend is a STUB: `input_specs()` provides precomputed frame
embeddings for the encoder; the decoder is a text decoder with cross-attention.
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        num_layers=12,                 # decoder layers
        encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        input_kind="tokens",           # decoder consumes text tokens
        act="gelu",
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt), num_layers=2, head_dim=16)
