"""gemma2-9b [dense] — alternating local/global attention, logit soft-caps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000  [arXiv:2408.00118]
head_dim=256, sliding window 4096, attn softcap 50, final softcap 30.
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        layer_pattern=("local", "global"),
        sliding_window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        rope_theta=1.0e4,
        tie_embeddings=True,
        embed_scale=True,
        act="gelu_tanh",
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
