"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887]
MoE on every other layer (AI21 Jamba), experts share the 14336 FFN width.
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        layer_pattern=("mamba", "mamba", "mamba", "attn",
                       "mamba", "mamba", "mamba", "mamba"),   # 1:7 attn:mamba
        num_experts=16,
        experts_per_token=2,
        moe_d_ff=14336,
        moe_every=2,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        rope_theta=1.0e6,
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
