"""gemma3-1b [dense] — 5:1 local:global attention, 262k vocab.

26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144  [hf:google/gemma-3-1b-pt]
head_dim=256, sliding window 512, tied embeddings scaled by sqrt(d).
The 262k-row embedding/unembedding crossbar dominates #cells — the EMT showcase.
"""
from repro.models.config import ModelConfig
from repro.configs.common import emt_preset, shrink


def build(emt=None) -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        num_layers=26,
        d_model=1152,
        num_heads=4,
        num_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262144,
        layer_pattern=("local", "local", "local", "local", "local", "global"),
        sliding_window=512,
        rope_theta=1.0e6,
        qk_norm=True,
        tie_embeddings=True,
        embed_scale=True,
        act="gelu_tanh",
        emt=emt or emt_preset(),
    )


def smoke(emt=None) -> ModelConfig:
    return shrink(build(emt))
