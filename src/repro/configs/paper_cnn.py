"""The paper's own evaluation models — VGG/ResNet family for 32x32 images.

`vgg16_cifar` / `resnet18_cifar` follow the paper's CIFAR-10 experiments (§5,
Table 1).  `*_small` variants are CPU-trainable reductions used by the in-repo
reproduction runs (examples/paper_repro.py) — same code path, fewer channels.
"""
from repro.models.cnn import CNNConfig
from repro.configs.common import emt_preset


def vgg16_cifar(emt=None) -> CNNConfig:
    return CNNConfig(name="vgg16_cifar", arch="vgg",
                     channels=(64, 128, 256), blocks_per_stage=2,
                     num_classes=10, emt=emt or emt_preset())


def resnet18_cifar(emt=None) -> CNNConfig:
    return CNNConfig(name="resnet18_cifar", arch="resnet",
                     channels=(64, 128, 256), blocks_per_stage=2,
                     num_classes=10, emt=emt or emt_preset())


def vgg_small(emt=None) -> CNNConfig:
    return CNNConfig(name="vgg_small", arch="vgg",
                     channels=(16, 32), blocks_per_stage=1,
                     num_classes=4, image_size=16, emt=emt or emt_preset())


def resnet_small(emt=None) -> CNNConfig:
    return CNNConfig(name="resnet_small", arch="resnet",
                     channels=(16, 32), blocks_per_stage=1,
                     num_classes=4, image_size=16, emt=emt or emt_preset())
