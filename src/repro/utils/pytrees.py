"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of elements across all array leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_size_bytes(tree) -> int:
    """Total bytes across all array/ShapeDtypeStruct leaves."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def flatten_with_paths(tree):
    """Yield (path_string, leaf) pairs with '/'-joined dict keys."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out
