from repro.utils.pytrees import tree_size_bytes, tree_param_count, flatten_with_paths
