"""Technique A — device-enhanced fluctuation sampling.

The paper augments the dataset with fluctuation samples ``S ~ R`` (Eqs. 7-12): every
read of a stored weight returns ``r_l(w, rho)`` with a fresh RTN state ``l``.  During
training the forward pass therefore sees ``w * (1 + a_l * sigma_rel(rho))``.

Two sampling backends:

* ``threefry`` — paper-faithful: ``jax.random.categorical`` from a split PRNG key.
  This is what a PyTorch/GPU implementation does; it costs a full weight-shaped
  random tensor in HBM per step.
* ``hash``     — TPU-codesigned: counter-based hash of (seed, coords) from
  :mod:`repro.core.hashrng`; bit-exact with the Pallas kernels, no HBM traffic when
  fused on-chip.

Granularity (`per_read` is the paper's exact model; the coarser modes are standard
noise-injection QAT estimators with identical marginals — see DESIGN.md §3.1):

* ``per_read``: independent sample per (batch_elem, k, n) read — O(B*K*N) samples;
  affordable only for the paper-scale CNN experiments.
* ``per_step``: independent sample per weight element per step, shared across the
  batch — O(K*N); the default for LM-scale training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashrng
from repro.core.device import DeviceModel


@dataclasses.dataclass(frozen=True)
class NoiseConfig:
    backend: str = "hash"          # "hash" | "threefry"
    granularity: str = "per_step"  # "per_step" | "per_read"
    enabled: bool = True


def sample_state_offsets_threefry(key, shape, device: DeviceModel):
    """Paper-faithful categorical state sampling."""
    logits = jnp.log(jnp.asarray(device.state_probs, jnp.float32))
    state = jax.random.categorical(key, logits, shape=shape)
    table = jnp.asarray(device.state_offsets, jnp.float32)
    return table[state]


def sample_state_offsets_hash(seed, shape, device: DeviceModel, plane=0,
                              row0=0, col0=0):
    """Counter-hash state sampling (TPU-codesigned path).

    2D tail of `shape` is hashed over (row, col); leading dims are folded into the
    plane counter so every batch slice gets independent draws.
    """
    if len(shape) == 1:
        shape = (1,) + tuple(shape)
        out = hashrng.tile_state_offsets(
            seed, row0, col0, shape, device.state_offsets, device.state_probs, plane)
        return out[0]
    if len(shape) == 2:
        return hashrng.tile_state_offsets(
            seed, row0, col0, shape, device.state_offsets, device.state_probs, plane)
    # fold leading dims into independent planes
    lead = int(jnp.prod(jnp.asarray(shape[:-2])))
    body = tuple(shape[-2:])
    planes = [
        hashrng.tile_state_offsets(seed, row0, col0, body, device.state_offsets,
                                   device.state_probs, plane * 131071 + i + 1)
        for i in range(lead)
    ]
    return jnp.stack(planes).reshape(shape)


def fluctuate(w, rho, device: DeviceModel, cfg: NoiseConfig, *,
              key: Optional[jax.Array] = None, seed=0, plane=0):
    """Return the sampled read value  w~ = r_l(w, rho)  (technique A forward).

    Gradients: flow through both `w` (straight-through on the multiplicative state,
    which is treated as data) and `rho` (through sigma_rel — this is what lets the
    optimizer trade accuracy for energy, Fig. 7).
    """
    if not cfg.enabled:
        return w
    if cfg.backend == "threefry":
        if key is None:
            raise ValueError("threefry backend needs a PRNG key")
        offs = sample_state_offsets_threefry(key, w.shape, device)
    elif cfg.backend == "hash":
        offs = sample_state_offsets_hash(seed, w.shape, device, plane=plane)
    else:
        raise ValueError(f"unknown noise backend {cfg.backend!r}")
    offs = jax.lax.stop_gradient(offs.astype(jnp.float32))
    sig = device.sigma_rel(rho)
    # multiply in the weight's own dtype: upcasting w to fp32 and back doubles
    # the weight-stream traffic of every analog layer (§Perf cell-B it.3); the
    # noise factor is computed in fp32 and rounded once (|1 - factor| ~ sigma,
    # so a bf16 rounding of the factor is ~0.4% of the noise itself).
    factor = (1.0 + offs * sig).astype(w.dtype)
    return w * factor
