"""Technique C — low-fluctuation bit-serial decomposition (paper §4.3).

An activation quantized to integer level ``q`` is fed to the crossbar one binary
digit at a time (Eq. 14): ``x = sum_p delta_p 2^p``.  Each bit-plane read draws an
*independent* fluctuation sample ``w(p)`` (independent RTN states), so the
accumulated output

    O_new = sum_p 2^p * delta_p * w(p)

has std ``sqrt(sum 4^p delta_p^2) * sigma(w)`` — strictly below the single-read std
``(sum 2^p delta_p) * sigma(w)`` whenever more than one bit is set (Eqs. 16-18) —
and energy ``rho * sum_p delta_p`` below ``rho * x`` (Eqs. 19-20).

The jnp implementation here is the *oracle* for the Pallas kernel
(:mod:`repro.kernels.emt_bitserial`) and the reference path used by dry-runs.
Backward pass: the decomposition is a zero-mean perturbation of the ideal matmul, so
we give it the ideal-matmul VJP (standard noise-STE; see DESIGN.md §3).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashrng
from repro.core.device import DeviceModel


def bit_plane(mag, p):
    """delta_p of non-negative integer-valued float array `mag` (paper Eq. 14)."""
    return jnp.floor(mag / (2.0 ** p)) % 2.0


def popcount_levels(mag, bits):
    """sum_p delta_p  — number of crossbar reads a level costs (Eq. 19)."""
    return sum(bit_plane(mag, p) for p in range(bits))


def sigma_ratio_theory(levels, bits):
    """Per-element theoretical sigma(O_new)/sigma(O_ori) from Eqs. 16-17.

    levels: non-negative integer-valued array. Returns ratio (1.0 where level==0 or a
    single bit is set — decomposition only helps multi-bit levels).
    """
    num = jnp.zeros_like(levels, dtype=jnp.float32)
    den = jnp.zeros_like(levels, dtype=jnp.float32)
    for p in range(bits):
        d = bit_plane(levels, p).astype(jnp.float32)
        num = num + (4.0 ** p) * d
        den = den + (2.0 ** p) * d
    return jnp.where(den > 0, jnp.sqrt(num) / jnp.maximum(den, 1e-9), 1.0)


def _bitserial_fwd(xq, w, rho, device: DeviceModel, bits: int, seed, base_plane):
    """Core loop: xq integer levels (may be negative), w already quantized.

    Per plane p: independent hash-noise draw on w, matmul of the signed bit-plane,
    scaled 2^p accumulation (exactly the analog timing diagram of Fig. 8(b)).
    """
    sign = jnp.sign(xq)
    mag = jnp.abs(xq)
    k, n = w.shape[-2], w.shape[-1]
    sig = device.sigma_rel(rho)
    acc = None
    for p in range(bits):
        offs = hashrng.tile_state_offsets(
            seed, 0, 0, (k, n), device.state_offsets, device.state_probs,
            plane=base_plane + p)
        wn = w * (1.0 + offs.astype(w.dtype) * sig.astype(w.dtype))
        planes = (sign * bit_plane(mag, p)).astype(w.dtype)
        term = (2.0 ** p) * jnp.matmul(planes, wn)
        acc = term if acc is None else acc + term
    return acc


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def bitserial_matmul_ref(xq, w, rho, device: DeviceModel, bits: int,
                         seed=0, base_plane=0):
    """y = bit-serial noisy matmul; oracle for the Pallas kernel.

    xq: (..., K) integer-valued float levels; w: (K, N); rho: scalar.
    """
    return _bitserial_fwd(xq, w, rho, device, bits, seed, base_plane)


def _fwd(xq, w, rho, device, bits, seed, base_plane):
    y = _bitserial_fwd(xq, w, rho, device, bits, seed, base_plane)
    return y, (xq, w, rho)


def _bwd(device, bits, res, g):
    # Ideal-matmul VJP (noise treated as zero-mean data perturbation).
    xq, w, rho = res
    gx = jnp.matmul(g, w.T).astype(xq.dtype)
    lead = int(np.prod(xq.shape[:-1]))
    gw = jnp.matmul(xq.reshape(lead, -1).T.astype(jnp.float32),
                    g.reshape(lead, -1).astype(jnp.float32)).astype(w.dtype)
    return gx, gw, jnp.zeros_like(rho), None, None


bitserial_matmul_ref.defvjp(_fwd, _bwd)
