"""Symmetric fake-quantization with straight-through estimators.

The paper fine-tunes with quantized weights *and* activations (§5).  Cells store a
bounded conductance, so weights are quantized to ``w_bits`` symmetric integer levels;
activations (the analog input lines / DAC levels) to ``a_bits`` levels.  Technique C
additionally requires activations as explicit integer levels so they can be read out
bit-serially (see :mod:`repro.core.decompose`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    w_bits: int = 8
    a_bits: int = 8
    enabled: bool = True
    # per-channel scales for weights (last dim), per-tensor for activations
    per_channel: bool = True
    # Per-row (per-sample) activation DAC scale instead of per-tensor: each
    # token's input-line levels are scaled by its own max, so quantization
    # never couples co-tenant batch rows.  The per-tensor default is the
    # paper's model (one shared DAC reference per array read) but makes token
    # streams occupancy-sensitive at the LSB in serving (ROADMAP "Known
    # subtlety"); enable this for occupancy-independent analog serving.
    a_per_row: bool = False


def _ste(x, q):
    """Straight-through: forward q, backward identity."""
    return x + jax.lax.stop_gradient(q - x)


def symmetric_scale(x, bits, axis=None, eps=1e-8):
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps) / qmax


def fake_quant(x, bits, axis=None):
    """Quantize-dequantize with STE. Returns (x_q_dequant, scale)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jax.lax.stop_gradient(symmetric_scale(x, bits, axis=axis))
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return _ste(x, q * scale), scale


def quant_levels(x, bits, axis=None):
    """Integer levels + scale (no dequant); levels in [-qmax, qmax].

    Forward: rounded integers. Backward: d(levels)/dx = 1/scale via STE, so training
    through the bit-serial path still works.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jax.lax.stop_gradient(symmetric_scale(x, bits, axis=axis))
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q = _ste(x / scale, q)
    return q, scale


def quantize_weights(w, cfg: QuantConfig):
    if not cfg.enabled:
        return w, None
    axis = tuple(range(w.ndim - 1)) if cfg.per_channel else None
    wq, scale = fake_quant(w, cfg.w_bits, axis=axis)
    return wq, scale


def quantize_activations(x, cfg: QuantConfig):
    if not cfg.enabled:
        return x, None
    xq, scale = fake_quant(x, cfg.a_bits, axis=None)
    return xq, scale
