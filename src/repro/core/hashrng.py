"""Counter-based hash RNG shared by Pallas kernels and pure-jnp reference paths.

TPU co-design: technique A needs a fresh fluctuation sample *per read* of every weight
element.  Materializing those samples with a stateful RNG costs an extra weight-sized
HBM stream per step.  Instead we derive noise as a pure function of

    (seed, plane, global_row, global_col)

with a cheap avalanche hash (two rounds of the murmur3/'lowbias32' finalizer over a
Weyl-sequence counter).  Inside a Pallas kernel the same function runs on VREGs over a
``broadcasted_iota`` — zero HBM traffic; in the jnp reference it lowers to ~10 fused
elementwise uint32 ops.  Kernel and reference are bit-exact by construction.

All functions are usable both inside ``pl.pallas_call`` bodies and in plain jnp code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_U = jnp.uint32
# odd constants (murmur3 / splitmix / lowbias32 lineage)
_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35
_M1 = 0x7FEB352D
_M2 = 0x846CA68B


def _finalize(x):
    x = x ^ (x >> 16)
    x = x * _U(_M1)
    x = x ^ (x >> 15)
    x = x * _U(_M2)
    x = x ^ (x >> 16)
    return x


def hash_counters(seed, row, col, plane=0):
    """Hash integer counter arrays (uint32) into uniform uint32.

    `row`/`col` are arrays (broadcastable); `seed`/`plane` scalars or arrays.
    """
    h = (row.astype(_U) * _U(_C1)) ^ (col.astype(_U) * _U(_C2))
    h = h ^ (_U(plane) * _U(_C3)) ^ _U(seed)
    h = _finalize(h)
    # second round for avalanche quality
    h = _finalize(h ^ _U(0x68E31DA4))
    return h


def tile_uniform_bits(seed, row0, col0, shape, plane=0):
    """uint32 uniform bits for a (rows, cols) tile whose global origin is (row0, col0).

    Works inside Pallas kernels: ``broadcasted_iota`` + elementwise uint ops only.
    """
    rows = jax.lax.broadcasted_iota(_U, shape, 0) + _U(row0)
    cols = jax.lax.broadcasted_iota(_U, shape, 1) + _U(col0)
    return hash_counters(seed, rows, cols, plane)


def bits_to_state(bits, probs):
    """Map uniform uint32 -> categorical state index given static state probs."""
    # u in [0, 1)
    u = bits.astype(jnp.float32) * (1.0 / 4294967296.0)
    state = jnp.zeros(bits.shape, jnp.int32)
    cum = 0.0
    for i, p in enumerate(probs[:-1]):
        cum += p
        state = jnp.where(u >= cum, i + 1, state)
    return state


def state_offset_from_bits(bits, offsets, probs):
    """uniform bits -> normalized RTN state offset a_l (float32).

    Uses only scalar literals (no captured constant arrays) so the same code can run
    inside a Pallas kernel body.
    """
    state = bits_to_state(bits, probs)
    out = jnp.full(bits.shape, float(offsets[0]), jnp.float32)
    # small static table: select-chain is cheaper than a gather on TPU VREGs
    for i in range(1, len(offsets)):
        out = jnp.where(state == i, float(offsets[i]), out)
    return out


def tile_state_offsets(seed, row0, col0, shape, offsets, probs, plane=0):
    """Fused: tile coords -> RTN normalized offsets. Pallas- and jnp-safe."""
    return state_offset_from_bits(
        tile_uniform_bits(seed, row0, col0, shape, plane), offsets, probs)
