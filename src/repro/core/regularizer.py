"""Technique B — energy regularization (paper §4.2, Eq. 13).

    L(w, rho) = L0(w, rho) + lambda * sum_t alpha_t * rho * |w_t|

* ``rho`` is a *trainable* per-layer energy coefficient, parametrized through a
  softplus so it stays positive; gradient descent co-optimizes accuracy (through the
  fluctuation amplitude ``sigma_rel(rho)`` in the forward pass) and energy (through
  this term) — Fig. 7.
* ``alpha_t`` is the number of reads of cell ``t`` per inference — for a dense layer
  computing T tokens it is T (one analog read per output row per token), times the
  bit count under bit-serial decomposition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


RHO_MIN = 1e-3


def rho_init_raw(rho0: float) -> float:
    """Inverse softplus so that softplus(raw) + RHO_MIN == rho0."""
    x = max(rho0 - RHO_MIN, 1e-6)
    return float(np.log(np.expm1(x))) if x < 30 else float(x)


def rho_from_raw(rho_raw):
    return jax.nn.softplus(rho_raw) + RHO_MIN


def layer_reg_term(w, rho, alpha: float):
    """alpha * rho * sum|w|  — differentiable in both w and rho."""
    return alpha * rho * jnp.sum(jnp.abs(w.astype(jnp.float32)))


def total_energy_loss(reg_terms, lam: float):
    """lambda * sum over layers; reg_terms is a list/pytree of scalars."""
    total = sum(jax.tree.leaves(reg_terms)) if reg_terms else 0.0
    return lam * total
