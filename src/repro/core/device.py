"""EMT device model — random-telegraph-noise (RTN) read fluctuation + energy.

The paper (§3, Fig. 2) models an analog EMT cell storing weight ``w`` with energy
coefficient ``rho``:

* a read returns ``r_l(w, rho)`` where ``l`` is the cell's (random) RTN state,
* the *fluctuation amplitude* (std of the read relative to ``w``) shrinks as ``rho``
  grows (Ielmini et al. [25]: RTN relative amplitude decreases with programming
  current/energy),
* read energy is proportional to ``rho`` and the stored weight magnitude
  (Fig. 2(a), Eq. 13/19): ``E_read = rho * |w| * x_level``.

We parametrize states symmetrically:

    r_l(w, rho) = w * (1 + a_l * sigma_rel(rho)),   sigma_rel(rho) = A / rho**beta

with state offsets ``a_l`` and probabilities ``p_l`` normalized so that
``sum_l p_l a_l = 0`` (unbiased reads) and ``sum_l p_l a_l^2 = 1`` (``sigma_rel`` *is*
the relative std).  The two-state 50/50 case of Fig. 2(b) is ``a = (-1, +1)``.

Everything is a plain dataclass of floats + tuples so it can be closed over by jitted
functions without becoming a traced value.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Fluctuation-intensity presets (paper §5.2, Fig. 10: weak / normal / strong).
INTENSITY_SCALE = {"weak": 0.5, "normal": 1.0, "strong": 2.0}


def _normalize_states(offsets: Tuple[float, ...], probs: Tuple[float, ...]):
    """Shift/scale state offsets so reads are unbiased with unit relative variance."""
    a = np.asarray(offsets, np.float64)
    p = np.asarray(probs, np.float64)
    p = p / p.sum()
    a = a - (p * a).sum()
    var = (p * a * a).sum()
    if var > 0:
        a = a / math.sqrt(var)
    return tuple(float(v) for v in a), tuple(float(v) for v in p)


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Parametric RTN model of one EMT technology corner."""
    # sigma_rel(rho) = amplitude * intensity_scale / rho**beta
    amplitude: float = 0.08
    beta: float = 0.5
    intensity: str = "normal"
    # RTN states (offsets are re-normalized to zero-mean / unit-variance).
    state_offsets: Tuple[float, ...] = (-1.0, 1.0)
    state_probs: Tuple[float, ...] = (0.5, 0.5)
    # Energy model: E_mac = e_mac * rho * |w| * x_level   [pJ]
    #               E_peripheral = e_read * (#row reads)   [pJ]  (ADC/driver overhead —
    # this is what makes depthwise/small-fan-in layers inefficient, paper §5.1).
    e_mac: float = 0.05
    e_read: float = 0.4
    rho_min: float = 1e-3

    def __post_init__(self):
        a, p = _normalize_states(self.state_offsets, self.state_probs)
        object.__setattr__(self, "state_offsets", a)
        object.__setattr__(self, "state_probs", p)
        if len(a) != len(p):
            raise ValueError("state offsets/probs length mismatch")

    @property
    def num_states(self) -> int:
        return len(self.state_offsets)

    @property
    def intensity_scale(self) -> float:
        return INTENSITY_SCALE[self.intensity]

    # ---- fluctuation ------------------------------------------------------
    def sigma_rel(self, rho):
        """Relative read std given energy coefficient rho (elementwise, traceable)."""
        rho = jnp.maximum(rho, self.rho_min)
        return self.amplitude * self.intensity_scale / jnp.power(rho, self.beta)

    def read_value(self, w, rho, state_offset):
        """r_l(w, rho) for a (sampled) normalized state offset a_l."""
        return w * (1.0 + state_offset * self.sigma_rel(rho))

    # ---- energy ------------------------------------------------------------
    def mac_energy(self, rho, abs_w_sum, x_level_mean, n_reads_per_cell):
        """Total MAC (cell) energy of reading a crossbar `n_reads_per_cell` times.

        abs_w_sum:        sum(|w|) over the stored array
        x_level_mean:     mean analog input level in [0, 1] (or mean popcount for
                          bit-serial reads — Eq. 19)
        n_reads_per_cell: alpha_t in Eq. 13 — how many times each cell is read.
        """
        return self.e_mac * rho * abs_w_sum * x_level_mean * n_reads_per_cell

    def peripheral_energy(self, n_row_reads):
        """Driver/ADC overhead proportional to the number of row-read operations."""
        return self.e_read * n_row_reads

    def with_intensity(self, intensity: str) -> "DeviceModel":
        return dataclasses.replace(self, intensity=intensity)


# A mildly multi-state corner (4-state RTN) used in robustness tests.
def four_state_device(**kw) -> DeviceModel:
    return DeviceModel(state_offsets=(-1.5, -0.5, 0.5, 1.5),
                       state_probs=(0.15, 0.35, 0.35, 0.15), **kw)


DEFAULT_DEVICE = DeviceModel()


# ---------------------------------------------------------------------------
# technology-corner registry
# ---------------------------------------------------------------------------
# Named device corners for heterogeneous placement (docs/device_models.md).
# Parameters are anchored to the paper's model shape (§3, Fig. 2) and the cited
# device literature, not to one measured chip:
#
# * pcm  — phase-change memory, the paper's reference cell (Ielmini et al. [25]
#   RTN amplitude/rho trend): the DEFAULT_DEVICE parameters.
# * rram — filamentary RRAM: stronger RTN at equal programming energy
#   (larger amplitude, slightly weaker rho suppression) but cheaper reads.
# * mlc2 / mlc4 — multi-level-cell corners: 2-state vs 4-state RTN; the
#   4-state corner models a cell whose traps expose intermediate levels.
# * sram_digital — digital CMOS fallback (SRAM-CiM): deterministic reads
#   (amplitude 0 — quantization still applies), MAC energy dominated by the
#   digital adder tree rather than rho-scaled cell current.
_REGISTRY = {
    "default": DEFAULT_DEVICE,
    "pcm": DeviceModel(amplitude=0.08, beta=0.5, e_mac=0.05, e_read=0.4),
    "rram": DeviceModel(amplitude=0.12, beta=0.4, e_mac=0.03, e_read=0.25),
    "mlc2": DeviceModel(amplitude=0.10, beta=0.5, e_mac=0.06, e_read=0.45),
    "mlc4": four_state_device(amplitude=0.10, beta=0.5, e_mac=0.06,
                              e_read=0.45),
    "sram_digital": DeviceModel(amplitude=0.0, beta=0.5, e_mac=0.02,
                                e_read=0.08),
}


def register_device(name: str, model: DeviceModel,
                    overwrite: bool = False) -> DeviceModel:
    """Register a user-defined technology corner under `name`."""
    if not isinstance(model, DeviceModel):
        raise TypeError(f"expected DeviceModel, got {type(model).__name__}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"device corner {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = model
    return model


def get_device(name: str) -> DeviceModel:
    """Look up a registered technology corner by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown device corner {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def device_names():
    return sorted(_REGISTRY)
