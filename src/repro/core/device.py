"""EMT device model — random-telegraph-noise (RTN) read fluctuation + energy.

The paper (§3, Fig. 2) models an analog EMT cell storing weight ``w`` with energy
coefficient ``rho``:

* a read returns ``r_l(w, rho)`` where ``l`` is the cell's (random) RTN state,
* the *fluctuation amplitude* (std of the read relative to ``w``) shrinks as ``rho``
  grows (Ielmini et al. [25]: RTN relative amplitude decreases with programming
  current/energy),
* read energy is proportional to ``rho`` and the stored weight magnitude
  (Fig. 2(a), Eq. 13/19): ``E_read = rho * |w| * x_level``.

We parametrize states symmetrically:

    r_l(w, rho) = w * (1 + a_l * sigma_rel(rho)),   sigma_rel(rho) = A / rho**beta

with state offsets ``a_l`` and probabilities ``p_l`` normalized so that
``sum_l p_l a_l = 0`` (unbiased reads) and ``sum_l p_l a_l^2 = 1`` (``sigma_rel`` *is*
the relative std).  The two-state 50/50 case of Fig. 2(b) is ``a = (-1, +1)``.

Everything is a plain dataclass of floats + tuples so it can be closed over by jitted
functions without becoming a traced value.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Fluctuation-intensity presets (paper §5.2, Fig. 10: weak / normal / strong).
INTENSITY_SCALE = {"weak": 0.5, "normal": 1.0, "strong": 2.0}


def _normalize_states(offsets: Tuple[float, ...], probs: Tuple[float, ...]):
    """Shift/scale state offsets so reads are unbiased with unit relative variance."""
    a = np.asarray(offsets, np.float64)
    p = np.asarray(probs, np.float64)
    p = p / p.sum()
    a = a - (p * a).sum()
    var = (p * a * a).sum()
    if var > 0:
        a = a / math.sqrt(var)
    return tuple(float(v) for v in a), tuple(float(v) for v in p)


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Parametric RTN model of one EMT technology corner."""
    # sigma_rel(rho) = amplitude * intensity_scale / rho**beta
    amplitude: float = 0.08
    beta: float = 0.5
    intensity: str = "normal"
    # RTN states (offsets are re-normalized to zero-mean / unit-variance).
    state_offsets: Tuple[float, ...] = (-1.0, 1.0)
    state_probs: Tuple[float, ...] = (0.5, 0.5)
    # Energy model: E_mac = e_mac * rho * |w| * x_level   [pJ]
    #               E_peripheral = e_read * (#row reads)   [pJ]  (ADC/driver overhead —
    # this is what makes depthwise/small-fan-in layers inefficient, paper §5.1).
    #               E_static = e_static * (#tile activations per step)  [pJ]
    # The static term is the macro-activation cost paid once per crossbar tile
    # per *step* regardless of how many input vectors stream through in that
    # step: sense-amp/ADC biasing, word-line drivers, and analog settling over
    # the read window.  It is what separates array-level from system-level
    # efficiency in measured silicon (Joshi et al., arXiv:1906.03138 report a
    # ~10x array-to-system gap at low batch) and what a multi-lane verify step
    # amortizes in speculative decoding (docs/control_plane.md).  Digital
    # corners clock-gate their macro and carry e_static = 0.
    e_mac: float = 0.05
    e_read: float = 0.4
    e_static: float = 0.0
    rho_min: float = 1e-3

    def __post_init__(self):
        a, p = _normalize_states(self.state_offsets, self.state_probs)
        object.__setattr__(self, "state_offsets", a)
        object.__setattr__(self, "state_probs", p)
        if len(a) != len(p):
            raise ValueError("state offsets/probs length mismatch")

    @property
    def num_states(self) -> int:
        return len(self.state_offsets)

    @property
    def intensity_scale(self) -> float:
        return INTENSITY_SCALE[self.intensity]

    # ---- fluctuation ------------------------------------------------------
    def sigma_rel(self, rho):
        """Relative read std given energy coefficient rho (elementwise, traceable)."""
        rho = jnp.maximum(rho, self.rho_min)
        return self.amplitude * self.intensity_scale / jnp.power(rho, self.beta)

    def read_value(self, w, rho, state_offset):
        """r_l(w, rho) for a (sampled) normalized state offset a_l."""
        return w * (1.0 + state_offset * self.sigma_rel(rho))

    # ---- energy ------------------------------------------------------------
    def mac_energy(self, rho, abs_w_sum, x_level_mean, n_reads_per_cell):
        """Total MAC (cell) energy of reading a crossbar `n_reads_per_cell` times.

        abs_w_sum:        sum(|w|) over the stored array
        x_level_mean:     mean analog input level in [0, 1] (or mean popcount for
                          bit-serial reads — Eq. 19)
        n_reads_per_cell: alpha_t in Eq. 13 — how many times each cell is read.
        """
        return self.e_mac * rho * abs_w_sum * x_level_mean * n_reads_per_cell

    def peripheral_energy(self, n_row_reads):
        """Driver/ADC overhead proportional to the number of row-read operations."""
        return self.e_read * n_row_reads

    def static_energy(self, n_tile_activations):
        """Per-step macro-activation cost: `n_tile_activations` crossbar tiles
        were biased/settled for this step window, independent of how many
        input lanes streamed through them."""
        return self.e_static * n_tile_activations

    def with_intensity(self, intensity: str) -> "DeviceModel":
        return dataclasses.replace(self, intensity=intensity)


# A mildly multi-state corner (4-state RTN) used in robustness tests.
def four_state_device(**kw) -> DeviceModel:
    return DeviceModel(state_offsets=(-1.5, -0.5, 0.5, 1.5),
                       state_probs=(0.15, 0.35, 0.35, 0.15), **kw)


DEFAULT_DEVICE = DeviceModel()


# ---------------------------------------------------------------------------
# technology-corner registry
# ---------------------------------------------------------------------------
# Named device corners for heterogeneous placement (docs/device_models.md).
# The corner presets are *calibrated* against published in-memory-compute
# silicon rather than the paper's dimensionless defaults — the full derivation
# with the operating-point arithmetic lives in docs/device_models.md
# ("Calibration" section); the headline anchors are:
#
# * pcm  — computational phase-change memory, anchored to Joshi et al.,
#   arXiv:1906.03138: ~0.1 pJ per analog MAC at the array level at their
#   mixed-precision operating point, an 8-bit-class ADC/sense bank per
#   128-column tile (~1.5 pJ/conversion -> ~200 pJ per tile row-read op),
#   and a reported ~10x array-to-system efficiency gap at low batch that we
#   model as a per-tile static activation cost of ~4 nJ per step window.
#   RTN amplitude/beta keep the Ielmini et al. [25] trend of the paper.
# * rram — filamentary RRAM / nvCiM, anchored to Yan et al.,
#   arXiv:2205.13018: lower read voltages/currents than PCM (~0.6x MAC and
#   sensing energy) but markedly stronger device-to-device + read
#   fluctuation at equal programming energy (larger amplitude, weaker rho
#   suppression beta).
# * mlc2 / mlc4 — multi-level-cell corners: 2-state vs 4-state RTN; denser
#   storage but higher read/sense cost per cell and noisier reads.
# * sram_digital — digital CMOS SRAM-CiM macro: deterministic reads
#   (amplitude 0 — quantization still applies), ~0.06 pJ/MAC (28nm 8T
#   macro class, ~30 TOPS/W INT8), no ADC (digital readout), and a
#   clock-gated macro with no static tax (e_static = 0).  This is the
#   cheap *draft* corner for heterogeneous speculative decoding.
#
# "default" keeps the historical paper-shape coefficients so existing
# single-device experiments and tests are unaffected by calibration.
_REGISTRY = {
    "default": DEFAULT_DEVICE,
    "pcm": DeviceModel(amplitude=0.08, beta=0.5, e_mac=0.0025,
                       e_read=200.0, e_static=4000.0),
    "rram": DeviceModel(amplitude=0.14, beta=0.4, e_mac=0.0015,
                        e_read=120.0, e_static=2400.0),
    "mlc2": DeviceModel(amplitude=0.10, beta=0.5, e_mac=0.003,
                        e_read=250.0, e_static=5000.0),
    "mlc4": four_state_device(amplitude=0.10, beta=0.5, e_mac=0.003,
                              e_read=250.0, e_static=5000.0),
    "sram_digital": DeviceModel(amplitude=0.0, beta=0.5, e_mac=0.0015,
                                e_read=10.0, e_static=0.0),
}


def register_device(name: str, model: DeviceModel,
                    overwrite: bool = False) -> DeviceModel:
    """Register a user-defined technology corner under `name`."""
    if not isinstance(model, DeviceModel):
        raise TypeError(f"expected DeviceModel, got {type(model).__name__}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"device corner {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = model
    return model


def get_device(name: str) -> DeviceModel:
    """Look up a registered technology corner by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown device corner {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def device_names():
    return sorted(_REGISTRY)
