"""Core: the paper's contribution — EMT device model + techniques A/B/C."""
from repro.core.device import (DeviceModel, DEFAULT_DEVICE, four_state_device,
                               INTENSITY_SCALE, register_device, get_device,
                               device_names)
from repro.core.noise import NoiseConfig, fluctuate
from repro.core.quant import QuantConfig, fake_quant, quant_levels
from repro.core.emt_linear import EMTConfig, IDEAL, emt_dense, dense_specs, new_aux, add_aux
from repro.core.placement import (LayerRule, DevicePlacement, as_placement,
                                  single, emt_for_corner, placement_to_dict,
                                  placement_from_dict, emt_to_dict,
                                  emt_from_dict, device_to_dict,
                                  device_from_dict)
from repro.core import decompose, regularizer, hashrng
