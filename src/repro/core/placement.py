"""Heterogeneous device placement — per-layer EMT technology corners.

The paper's premise is that EMT read instability and energy cost are device-
and layer-dependent (§5.1: the peripheral ``e_read`` term makes small-fan-in
layers inefficient; §5.2 sweeps weak/normal/strong corners).  A single global
``EMTConfig`` cannot express "attention on PCM, MLPs bit-serial on RRAM,
router digital", so model configs may instead carry a :class:`DevicePlacement`:
an ordered list of :class:`LayerRule` glob patterns over canonical layer paths,
resolved **at model-build time** into a static per-layer plan — jit still sees
only closed-over frozen dataclasses, exactly as with one global config.

Canonical layer paths (see docs/device_models.md):

    dec/layer_007/attn/{wq,wk,wv,wo}     attention projections
    dec/layer_007/xattn/{wq,wk,wv,wo}    enc-dec cross attention
    dec/layer_007/mlp/{wg,wu,wd}         dense GLU FFN
    dec/layer_007/moe/experts            stacked expert weights (one unit)
    dec/layer_007/moe/router             router (digital unless explicitly placed)
    dec/layer_007/mamba/{in,xp,dt,out}   SSM projections
    dec/layer_007/mlstm/{up,wq,wk,wv,wi,wf,down}
    dec/layer_007/slstm/{wz,wi,wf,wo,up,down}
    enc/layer_003/...                    encoder stack (enc-dec models)
    unembed                              LM head (tied or untied)
    s0b1/{c1,c2,proj}, head              CNN stages (models/cnn.py)

Rules are **first-match-wins**; unmatched paths fall back to ``default``.  A
plain ``EMTConfig`` auto-wraps into a zero-rule placement (:func:`as_placement`)
so every existing config, checkpoint, and call site keeps working unchanged.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import Optional, Tuple, Union

from repro.core.device import DeviceModel, get_device
from repro.core.emt_linear import EMTConfig, IDEAL
from repro.core.noise import NoiseConfig
from repro.core.quant import QuantConfig


def emt_for_corner(corner: str, mode: str = "analog", *,
                   intensity: str = "normal", rho_init: float = 4.0,
                   trainable_rho: Optional[bool] = None,
                   **kw) -> EMTConfig:
    """Build an EMTConfig on a registered technology corner.

    ``mode="ideal"`` returns a corner-labelled ideal config (digital fallback
    with no quantization). Unknown corner names raise ``KeyError``.
    """
    device = get_device(corner)            # raises KeyError on unknown corner
    if mode == "ideal":
        return EMTConfig(mode="ideal", quant=QuantConfig(enabled=False),
                         device=device, corner=corner)
    if trainable_rho is None:
        # a deterministic (amplitude-0) digital corner has no accuracy/energy
        # trade-off for rho gradients to navigate
        trainable_rho = device.amplitude > 0
    return EMTConfig(
        mode=mode,
        quant=QuantConfig(w_bits=8, a_bits=8, enabled=True),
        noise=NoiseConfig(backend="hash", granularity="per_step"),
        device=device.with_intensity(intensity),
        rho_init=rho_init,
        trainable_rho=trainable_rho,
        corner=corner,
        **kw)


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """Glob `pattern` over canonical layer paths -> `emt` config."""
    pattern: str
    emt: EMTConfig

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)

    @property
    def corner(self) -> str:
        return self.emt.corner or self.emt.mode


@dataclasses.dataclass(frozen=True)
class DevicePlacement:
    """Ordered first-match-wins rules + a default for unmatched paths."""
    rules: Tuple[LayerRule, ...] = ()
    default: EMTConfig = IDEAL

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            if not isinstance(r, LayerRule):
                raise TypeError(f"rules must be LayerRule, got {type(r).__name__}")

    # ---- resolution --------------------------------------------------------
    def match(self, path: str) -> Optional[EMTConfig]:
        """First explicit rule matching `path`, or None (default NOT applied).

        Used for sites that are digital unless placed (the MoE router)."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.emt
        return None

    def resolve(self, path: str) -> EMTConfig:
        """Per-layer config for `path`: first matching rule, else the default."""
        hit = self.match(path)
        return self.default if hit is None else hit

    # ---- conveniences ------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.default.active or any(r.emt.active for r in self.rules)

    @property
    def mode(self) -> str:
        """Representative mode (the default's) — display/back-compat only."""
        return self.default.mode

    def corners(self) -> Tuple[str, ...]:
        """All corner labels this placement can book energy under."""
        seen = []
        for emt in [r.emt for r in self.rules] + [self.default]:
            label = emt.corner or emt.mode
            if label not in seen:
                seen.append(label)
        return tuple(seen)


def single(emt: EMTConfig) -> DevicePlacement:
    """Wrap one global EMTConfig as a zero-rule placement (old behavior)."""
    return DevicePlacement(rules=(), default=emt)


@functools.lru_cache(maxsize=None)
def _coerce(emt) -> DevicePlacement:
    return emt if isinstance(emt, DevicePlacement) else single(emt)


def as_placement(emt: Union[EMTConfig, DevicePlacement]) -> DevicePlacement:
    """Normalize an `emt` field (EMTConfig or DevicePlacement) to a placement."""
    if not isinstance(emt, (EMTConfig, DevicePlacement)):
        raise TypeError(f"emt must be EMTConfig or DevicePlacement, "
                        f"got {type(emt).__name__}")
    return _coerce(emt)


# ---------------------------------------------------------------------------
# dict serialization (checkpoint `extra` metadata — ckpt/checkpoint.py)
# ---------------------------------------------------------------------------
def device_to_dict(dev: DeviceModel) -> dict:
    return {f.name: getattr(dev, f.name)
            for f in dataclasses.fields(DeviceModel)}


def device_from_dict(d: dict) -> DeviceModel:
    known = {f.name for f in dataclasses.fields(DeviceModel)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown DeviceModel fields {sorted(unknown)}")
    d = dict(d)
    for k in ("state_offsets", "state_probs"):
        if k in d:
            d[k] = tuple(d[k])
    return DeviceModel(**d)


def emt_to_dict(emt: EMTConfig) -> dict:
    d = {f.name: getattr(emt, f.name) for f in dataclasses.fields(EMTConfig)}
    d["quant"] = dataclasses.asdict(emt.quant)
    d["noise"] = dataclasses.asdict(emt.noise)
    d["device"] = device_to_dict(emt.device)
    return d


def emt_from_dict(d: dict) -> EMTConfig:
    d = dict(d)
    if "quant" in d:
        d["quant"] = QuantConfig(**d["quant"])
    if "noise" in d:
        d["noise"] = NoiseConfig(**d["noise"])
    if "device" in d:
        dev = d["device"]
        # a string refers to a registered corner (KeyError if unknown);
        # a dict carries the full parameters inline
        d["device"] = get_device(dev) if isinstance(dev, str) \
            else device_from_dict(dev)
    return EMTConfig(**d)


def placement_to_dict(p: Union[EMTConfig, DevicePlacement]) -> dict:
    p = as_placement(p)
    return {"rules": [{"pattern": r.pattern, "emt": emt_to_dict(r.emt)}
                      for r in p.rules],
            "default": emt_to_dict(p.default)}


def placement_from_dict(d: dict) -> DevicePlacement:
    rules = tuple(LayerRule(r["pattern"], emt_from_dict(r["emt"]))
                  for r in d.get("rules", ()))
    return DevicePlacement(rules=rules, default=emt_from_dict(d["default"]))
