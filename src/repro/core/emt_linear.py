"""EMT dense layer — the paper's techniques A/B/C as a drop-in matmul.

``emt_dense`` replaces every projection in the framework's models (attention QKV/O,
GLU MLPs, MoE experts, routers, de/embeddings, SSM in/out projections, im2col convs).

Modes
-----
* ``ideal``      — plain (optionally fake-quantized) matmul; the GPU/baseline.
* ``analog``     — one crossbar read per MAC with RTN fluctuation (technique A), and
                   a trainable per-layer energy coefficient rho (technique B).
* ``bitserial``  — technique C: bit-serial decomposed reads with independent
                   fluctuation per bit-plane (lower sigma *and* lower energy, at a
                   latency cost).

Every call returns ``(y, aux)`` where ``aux`` carries the differentiable
energy-regularization term (Eq. 13), the analytic energy estimate in pJ, cell and
read counts — aggregated up the model with :func:`add_aux`.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceModel, DEFAULT_DEVICE
from repro.core.noise import NoiseConfig, fluctuate
from repro.core.quant import QuantConfig, quantize_weights, quant_levels
from repro.core import decompose, regularizer
from repro.nn.param import ParamSpec, fan_in_init, constant_init


@dataclasses.dataclass(frozen=True)
class EMTConfig:
    """How EMT simulation applies to the model's dense layers."""
    mode: str = "ideal"                      # ideal | analog | bitserial
    quant: QuantConfig = QuantConfig()
    noise: NoiseConfig = NoiseConfig()
    device: DeviceModel = DEFAULT_DEVICE
    rho_init: float = 4.0
    trainable_rho: bool = True
    use_pallas: bool = False                 # kernels only run/validate on TPU or interpret
    pallas_interpret: bool = False
    crossbar_tile: int = 128                 # physical array tile (alpha accounting)
    # "full": per-step sum|w| reductions (training needs them for the technique-B
    # loss anyway). "off": skip in-step accounting — serving uses precomputed
    # static per-layer sum|w| tables instead of re-reading all weights per token.
    energy_accounting: str = "full"
    # Beyond-paper serving optimization: store weights as int8 levels + per-column
    # scale (exactly the conductance levels an EMT crossbar stores) and dequantize
    # on-chip — halves weight HBM streaming for memory-bound decode. Serve-only.
    store_int8: bool = False
    # Technology-corner label (core/device.py registry) — stamps this layer's
    # energy/reads/cells into the per-corner aux breakdown. Empty: fall back
    # to the mode name.
    corner: str = ""

    @property
    def active(self) -> bool:
        return self.mode != "ideal"

    @property
    def corner_label(self) -> str:
        return self.corner or self.mode

    def replace(self, **kw) -> "EMTConfig":
        return dataclasses.replace(self, **kw)


IDEAL = EMTConfig(mode="ideal", quant=QuantConfig(enabled=False))


def _tag_plane(tag: str) -> int:
    """Stable per-layer noise plane derived from the layer's name."""
    return zlib.crc32(tag.encode()) & 0x7FFFFFF


def _int8_init(base_init):
    """Initialize int8 conductance levels by quantizing a float init."""
    def init(key, shape, dtype):
        wf = base_init(key, shape, jnp.float32)
        scale = jnp.max(jnp.abs(wf), axis=0, keepdims=True) / 127.0
        return jnp.clip(jnp.round(wf / jnp.maximum(scale, 1e-8)),
                        -127, 127).astype(jnp.int8)
    return init


def dense_specs(d_in: int, d_out: int, cfg: EMTConfig, *,
                axes=(None, None), dtype=jnp.float32, bias: bool = False,
                init=None) -> dict:
    """ParamSpec dict for one EMT dense layer (w [, b] [, rho_raw]).

    With cfg.store_int8 (serve-only), `w` is stored as int8 conductance levels
    plus a per-output-column fp32 scale — the exact representation an EMT
    crossbar holds — halving weight HBM streaming vs bf16.
    """
    base_init = init or fan_in_init(fan_axis=0)
    if cfg.active and cfg.store_int8:
        specs = {
            "w_int8": ParamSpec((d_in, d_out), jnp.int8, tuple(axes),
                                _int8_init(base_init)),
            "w_scale": ParamSpec((1, d_out), jnp.float32, (None, axes[1]),
                                 constant_init(1.0 / 127.0)),
        }
    else:
        specs = {
            "w": ParamSpec((d_in, d_out), dtype, tuple(axes), base_init),
        }
    if bias:
        specs["b"] = ParamSpec((d_out,), dtype, (axes[1],), constant_init(0.0))
    if cfg.active:
        specs["rho_raw"] = ParamSpec(
            (), jnp.float32, (), constant_init(regularizer.rho_init_raw(cfg.rho_init)))
    return specs


def quantize_tree_for_serving(params):
    """Convert a trained float checkpoint into int8 weight-streaming form:
    every dict holding 'w' (+'rho_raw') becomes {'w_int8','w_scale',...}."""
    if isinstance(params, dict):
        if "w" in params and "rho_raw" in params:
            w = params["w"].astype(jnp.float32)
            scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0
            q = jnp.clip(jnp.round(w / jnp.maximum(scale, 1e-8)),
                         -127, 127).astype(jnp.int8)
            out = {k: v for k, v in params.items() if k != "w"}
            out["w_int8"] = q
            out["w_scale"] = scale
            return out
        return {k: quantize_tree_for_serving(v) for k, v in params.items()}
    return params


def new_aux():
    # kv_reads: K/V cache elements actually read by decode attention (billed
    # only for mask-visible logical positions — zero-block gathers for
    # unallocated/padded block-table entries are free; models/attention.py).
    return {"energy_pj": jnp.float32(0.0), "reg": jnp.float32(0.0),
            "reads": jnp.float32(0.0), "kv_reads": jnp.float32(0.0),
            "cells": 0, "rho_sum": jnp.float32(0.0),
            "rho_layers": 0, "aux_loss": jnp.float32(0.0), "corners": {}}


def corner_entry(energy_pj, reads, cells):
    return {"energy_pj": jnp.float32(energy_pj), "reads": jnp.float32(reads),
            "cells": cells}


def add_aux(a, b):
    out = {k: a[k] + b[k] for k in a if k != "corners"}
    # per-corner breakdown: union-merge (corner labels are static python
    # strings from the placement, so the pytree structure stays jit-stable)
    corners = {k: dict(v) for k, v in a.get("corners", {}).items()}
    for name, c in b.get("corners", {}).items():
        if name in corners:
            corners[name] = {k: corners[name][k] + c[k] for k in c}
        else:
            corners[name] = dict(c)
    out["corners"] = corners
    return out


def _tokens(x) -> int:
    return int(np.prod(x.shape[:-1]))


def emt_dense(params: dict, x, cfg: EMTConfig, *, tag: str,
              seed=0, key: Optional[jax.Array] = None):
    """Apply the layer. Returns (y, aux).

    tag:  unique layer name — seeds the per-layer noise plane (hash backend) or the
          fold_in constant (threefry backend).
    seed: uint32 scalar (traced is fine) — typically derived from the training step,
          so technique A sees fresh fluctuation data every batch.
    """
    int8_weights = "w_int8" in params
    w = params["w_int8"] if int8_weights else params["w"]
    aux = new_aux()
    d_in, d_out = w.shape
    plane = _tag_plane(tag)

    if not cfg.active:
        y = x @ w
        if "b" in params:
            y = y + params["b"]
        return y, aux

    rho = regularizer.rho_from_raw(params["rho_raw"])
    if not cfg.trainable_rho:
        rho = jax.lax.stop_gradient(rho)

    # --- weights onto the crossbar: quantize (stored conductances) ----------
    if int8_weights:
        # already stored as conductance levels; dequantize on-chip (fuses into
        # the matmul input on TPU — weight HBM traffic stays int8-sized)
        wq = (w.astype(x.dtype) * params["w_scale"].astype(x.dtype))
    else:
        wq, _ = quantize_weights(w, cfg.quant)
    # --- activations onto the input lines: quantized DAC levels -------------
    # per_row: each batch row (token) gets its own DAC scale, so quantization
    # never couples co-tenant rows (occupancy-independent serving); per-tensor
    # is the paper's default and marginally cheaper.
    a_axis = -1 if cfg.quant.a_per_row else None
    levels, a_scale = quant_levels(x, cfg.quant.a_bits, axis=a_axis)

    n_tokens = _tokens(x)
    if cfg.mode == "analog":
        if key is not None:
            key = jax.random.fold_in(key, plane)
        wn = fluctuate(wq, rho, cfg.device, cfg.noise, key=key,
                       seed=seed, plane=plane)
        y = (levels * a_scale) @ wn
        # mean analog input level in LEVEL units (x = sum_p delta_p 2^p, Eq. 14) so
        # it is directly comparable with the bit-serial popcount of Eq. 19.
        x_level = jax.lax.stop_gradient(jnp.mean(jnp.abs(levels)))
        reads_per_cell = float(n_tokens)
    elif cfg.mode == "bitserial":
        if cfg.use_pallas:
            from repro.kernels import ops as kops  # lazy: kernels depend on core
            y_raw = kops.emt_bitserial_matmul(
                levels.reshape(-1, d_in), wq, rho, device=cfg.device,
                bits=cfg.quant.a_bits - 1, seed=seed, base_plane=plane,
                interpret=cfg.pallas_interpret)
            y_raw = y_raw.reshape(*x.shape[:-1], d_out)
        else:
            y_raw = decompose.bitserial_matmul_ref(
                levels, wq, rho, cfg.device, cfg.quant.a_bits - 1,
                seed=seed, base_plane=plane)
        y = y_raw * a_scale
        # energy counts actual bit reads (Eq. 19): popcount of levels
        pops = decompose.popcount_levels(jnp.abs(levels), cfg.quant.a_bits - 1)
        x_level = jax.lax.stop_gradient(jnp.mean(pops))
        reads_per_cell = float(n_tokens)  # per bit handled via x_level popcount
    else:
        raise ValueError(f"unknown EMT mode {cfg.mode!r}")

    if "b" in params:
        y = y + params["b"]

    if cfg.energy_accounting == "off":
        aux["cells"] = int(d_in * d_out)
        aux["corners"] = {cfg.corner_label: corner_entry(0.0, 0.0, aux["cells"])}
        return y, aux

    # --- accounting ----------------------------------------------------------
    w_norm = jax.lax.stop_gradient(
        jnp.sum(jnp.abs(wq.astype(jnp.float32))) / jnp.maximum(jnp.max(jnp.abs(wq)), 1e-8))
    rho_sg = jax.lax.stop_gradient(rho)
    # tile count of this layer on the crossbar fabric (fractional for layers
    # smaller than one tile — they still only bias a fraction of a macro)
    n_tiles = (d_in / cfg.crossbar_tile) * max(1.0, d_out / cfg.crossbar_tile)
    aux["energy_pj"] = (
        cfg.device.mac_energy(rho_sg, w_norm, x_level, reads_per_cell)
        + cfg.device.peripheral_energy(n_tokens * n_tiles)
        # static macro-activation cost: paid once per tile per step window,
        # NOT per streamed lane — this is what multi-lane verify amortizes.
        + cfg.device.static_energy(n_tiles))
    aux["energy_pj"] = jnp.float32(aux["energy_pj"])
    # Technique B loss term (Eq. 13): alpha * rho * sum|w|, alpha = reads per token
    # (normalized per-token so lambda has a model-size-independent meaning).
    aux["reg"] = regularizer.layer_reg_term(wq, rho, alpha=1.0) / d_out
    aux["reads"] = jnp.float32(n_tokens * d_in)
    aux["cells"] = int(d_in * d_out)
    aux["rho_sum"] = rho_sg
    aux["rho_layers"] = 1
    aux["corners"] = {cfg.corner_label: corner_entry(
        aux["energy_pj"], aux["reads"], aux["cells"])}
    return y, aux
