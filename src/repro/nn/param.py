"""Parameter-spec system.

Every model module declares its parameters as a nested dict of :class:`ParamSpec`,
which carries shape, dtype, *logical axis names*, and an initializer.  From the spec
tree we can

* materialize real parameters (``init_params``),
* build ``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run
  (``abstract_params`` — no allocation), and
* derive ``NamedSharding``s by mapping logical axes to mesh axes through a rule table
  (``param_shardings``).

This is the glue that makes the same model definition runnable on 1 CPU device and
compilable for a 512-chip multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def constant_init(value: float) -> Initializer:
    def init(key, shape, dtype):
        del key
        return jnp.full(shape, value, dtype)
    return init


def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def fan_in_init(scale: float = 1.0, fan_axis: int = -2) -> Initializer:
    """LeCun-style: stddev = scale / sqrt(fan_in). fan_axis indexes the input dim."""
    def init(key, shape, dtype):
        fan_in = shape[fan_axis] if len(shape) >= 2 else shape[0]
        std = scale / np.sqrt(max(1, fan_in))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    return init


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""
    shape: tuple
    dtype: Any = jnp.float32
    axes: tuple = ()            # logical axis name per dim, e.g. ("embed", "mlp")
    init: Initializer = dataclasses.field(default=normal_init())

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    """Materialize a spec tree into real arrays. Deterministic per tree path."""
    flat, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, max(1, len(flat)))
    leaves = [s.init(k, s.shape, s.dtype) for s, k in zip(flat, keys)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(specs):
    """ShapeDtypeStruct tree — used by the dry-run; never allocates."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec)


def axes_tree(specs):
    """Tree of logical-axes tuples mirroring the parameter tree."""
    return jax.tree.map(lambda s: tuple(s.axes), specs, is_leaf=_is_spec)


def logical_to_pspec(axes: Sequence[Optional[str]], rules: dict,
                     mesh: Mesh, shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to mesh axes through `rules`.

    A rule value may be None (replicate), a mesh-axis name, or a tuple of mesh-axis
    names. A mesh axis may be consumed at most once per param; later conflicting
    requests fall back to replication (standard MaxText-style behaviour).  When
    `shape` is given, mesh axes whose size does not divide the dim are dropped
    (e.g. a 1-KV-head cache dim is never sharded 16-way).
    """
    mesh_axes = tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    out = []
    for i, name in enumerate(axes):
        target = rules.get(name) if name is not None else None
        if target is None:
            out.append(None)
            continue
        targets = target if isinstance(target, tuple) else (target,)
        picked = []
        dim = None if shape is None else int(shape[i])
        for t in targets:
            if t in used or t not in mesh_axes:
                continue
            if dim is not None:
                factor = sizes[t]
                cur = 1
                for p in picked:
                    cur *= sizes[p]
                if dim % (cur * factor) != 0:
                    continue
            picked.append(t)
        for t in picked:
            used.add(t)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def param_shardings(specs, mesh: Mesh, rules: dict):
    """NamedSharding tree for a spec tree under the given mesh + rule table."""
    def one(s: ParamSpec):
        return NamedSharding(mesh, logical_to_pspec(s.axes, rules, mesh, s.shape))

    return jax.tree.map(one, specs, is_leaf=_is_spec)
