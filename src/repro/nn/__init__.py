from repro.nn.param import ParamSpec, init_params, abstract_params, axes_tree, param_shardings
