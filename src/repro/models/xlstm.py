"""xLSTM blocks — chunkwise-parallel mLSTM (matrix memory) and sLSTM.

TPU formulation (DESIGN.md §Arch-applicability):

* Gates are bounded (`f = sigmoid`, `i = exp(min(ĩ,0)) = sigmoid-like ≤ 1`) so all
  decay exponents are ≤ 0 and the running-max stabilizer of the original paper is
  unnecessary — the chunkwise form becomes plain linear algebra with no `while`
  loops (chunks python-unrolled, inter-chunk state algebra exact).
* sLSTM is implemented input-gated (recurrent R-matrices = 0) so the scalar-memory
  recurrence is a linear scan computable with `associative_scan`; the exact
  R-recurrent variant is available via `slstm_recurrent=True` (lax.scan; used in
  correctness tests, not in dry-run graphs).
* EMT: all projections (qkv/gates/up/down) are crossbar matmuls; the state update
  itself is not a stored-weight MAC and runs ideal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emt_linear import emt_dense, dense_specs, new_aux, add_aux
from repro.nn.param import ParamSpec, constant_init
from repro.models import common
from repro.models.config import ModelConfig
from repro.models.context import Ctx

MLSTM_CHUNK = 2048


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_specs(cfg: ModelConfig, tag: str = "") -> dict:
    D = cfg.d_model
    DI = 2 * D                       # projection factor 2 (xLSTM paper)
    H = cfg.num_heads
    e = cfg.emt_at
    return {
        "up": dense_specs(D, 2 * DI, e(f"{tag}/up"), axes=("embed", "mlp"),
                          dtype=cfg.dtype),
        "conv_w": ParamSpec((4, DI), cfg.dtype, (None, "mlp"), constant_init(0.1)),
        "conv_b": ParamSpec((DI,), cfg.dtype, ("mlp",), constant_init(0.0)),
        "wq": dense_specs(DI, DI, e(f"{tag}/wq"), axes=("mlp", "heads"),
                          dtype=cfg.dtype),
        "wk": dense_specs(DI, DI, e(f"{tag}/wk"), axes=("mlp", "heads"),
                          dtype=cfg.dtype),
        "wv": dense_specs(DI, DI, e(f"{tag}/wv"), axes=("mlp", "heads"),
                          dtype=cfg.dtype),
        "wi": dense_specs(DI, H, e(f"{tag}/wi"), axes=("mlp", None),
                          dtype=cfg.dtype, bias=True),
        "wf": dense_specs(DI, H, e(f"{tag}/wf"), axes=("mlp", None),
                          dtype=cfg.dtype, bias=True),
        "out_norm": common.rmsnorm_specs(DI),
        "down": dense_specs(DI, D, e(f"{tag}/down"), axes=("mlp", "embed"),
                            dtype=cfg.dtype),
    }


def _mlstm_chunk(q, k, v, log_f, log_i, C0, n0):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k,v: (B,H,c,hd); log_f, log_i: (B,H,c) (both ≤ 0). C0: (B,H,hd,hd),
    n0: (B,H,hd). Returns (y (B,H,c,hd), C1, n1).
    """
    hd = q.shape[-1]
    lfc = jnp.cumsum(log_f, axis=-1)                        # inclusive Π f up to t
    lf_total = lfc[..., -1]
    # intra-chunk decay matrix: d_tj = lfc_t - lfc_j + log_i_j   (j <= t)
    d = lfc[..., :, None] - lfc[..., None, :] + log_i[..., None, :]
    c = q.shape[2]
    tri = jnp.tril(jnp.ones((c, c), bool))
    att = jnp.einsum("bhtd,bhjd->bhtj", q, k) / np.sqrt(hd)
    att = att * jnp.exp(d) * tri
    y_intra = jnp.einsum("bhtj,bhjd->bhtd", att, v)
    n_intra = jnp.einsum("bhtj,bhjd->bhtd", jnp.exp(d) * tri, k)

    # inter-chunk: state from previous chunks decayed to t
    decay_t = jnp.exp(lfc)[..., None]                       # (B,H,c,1)
    y_inter = jnp.einsum("bhtd,bhde->bhte", q, C0) * decay_t / np.sqrt(hd)
    n_inter = n0[:, :, None] * decay_t

    n_t = n_intra + n_inter
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhtd,bhtd->bht", q, n_t)) / np.sqrt(hd), 1.0)
    y = (y_intra + y_inter) / denom[..., None]

    # state update to end of chunk
    w = jnp.exp(lf_total[..., None] - lfc + log_i)          # (B,H,c)
    C1 = jnp.exp(lf_total)[..., None, None] * C0 + \
        jnp.einsum("bhj,bhjd,bhje->bhde", w, k, v)
    n1 = jnp.exp(lf_total)[..., None] * n0 + jnp.einsum("bhj,bhjd->bhd", w, k)
    return y, C1, n1


def mlstm(params, x, cfg: ModelConfig, *, ctx: Ctx, tag: str, state=None):
    """Returns (y, aux, new_state); state = {"C": (B,H,hd,hd), "n": (B,H,hd),
    "conv": (B,3,DI)}."""
    B, S, D = x.shape
    H = cfg.num_heads
    DI = 2 * D
    hd = DI // H
    aux = new_aux()

    up, a = emt_dense(params["up"], x, cfg.emt_at(f"{tag}/up"), tag=f"{tag}/up", seed=ctx.seed,
                      key=ctx.key)
    aux = add_aux(aux, a)
    xm, z = jnp.split(up, 2, axis=-1)

    from repro.models.mamba import _causal_depthwise_conv
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_depthwise_conv(xm, params["conv_w"], params["conv_b"],
                                          conv_state)
    xc = jax.nn.silu(xc)

    outs = {}
    for nm, src in (("wq", xc), ("wk", xc), ("wv", xm)):
        o, a = emt_dense(params[nm], src, cfg.emt_at(f"{tag}/{nm}"), tag=f"{tag}/{nm}",
                         seed=ctx.seed, key=ctx.key)
        aux = add_aux(aux, a)
        outs[nm] = o.reshape(B, S, H, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    q, k, v = outs["wq"], outs["wk"], outs["wv"]

    gi, a = emt_dense(params["wi"], xc, cfg.emt_at(f"{tag}/wi"), tag=f"{tag}/wi", seed=ctx.seed,
                      key=ctx.key)
    aux = add_aux(aux, a)
    gf, a = emt_dense(params["wf"], xc, cfg.emt_at(f"{tag}/wf"), tag=f"{tag}/wf", seed=ctx.seed,
                      key=ctx.key)
    aux = add_aux(aux, a)
    log_i = -jax.nn.softplus(-gi.astype(jnp.float32)).transpose(0, 2, 1)  # ≤ 0
    log_f = jax.nn.log_sigmoid(gf.astype(jnp.float32)).transpose(0, 2, 1)  # ≤ 0

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state["C"]
    n0 = jnp.zeros((B, H, hd), jnp.float32) if state is None else state["n"]

    ys = []
    chunk = min(MLSTM_CHUNK, S)
    for s0 in range(0, S, chunk):
        sl = slice(s0, s0 + chunk)
        y, C0, n0 = _mlstm_chunk(q[:, :, sl], k[:, :, sl], v[:, :, sl],
                                 log_f[:, :, sl], log_i[:, :, sl], C0, n0)
        ys.append(y)
    y = jnp.concatenate(ys, axis=2)                          # (B,H,S,hd)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, DI).astype(cfg.dtype)
    y = common.rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out, a = emt_dense(params["down"], y, cfg.emt_at(f"{tag}/down"), tag=f"{tag}/down",
                       seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return out, aux, {"C": C0, "n": n0, "conv": new_conv}


def mlstm_state_specs(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    DI = 2 * cfg.d_model
    hd = DI // H
    return {
        "C": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, H, hd), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, 3, DI), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_specs(cfg: ModelConfig, tag: str = "") -> dict:
    D = cfg.d_model
    F = -(-4 * D // 3 // 128) * 128   # proj factor 4/3, aligned
    e = cfg.emt_at
    return {
        "wz": dense_specs(D, D, e(f"{tag}/wz"), axes=("embed", "mlp"),
                          dtype=cfg.dtype, bias=True),
        "wi": dense_specs(D, D, e(f"{tag}/wi"), axes=("embed", "mlp"),
                          dtype=cfg.dtype, bias=True),
        "wf": dense_specs(D, D, e(f"{tag}/wf"), axes=("embed", "mlp"),
                          dtype=cfg.dtype, bias=True),
        "wo": dense_specs(D, D, e(f"{tag}/wo"), axes=("embed", "mlp"),
                          dtype=cfg.dtype, bias=True),
        # exact-variant recurrent matrices (used only when slstm_recurrent=True)
        "rz": ParamSpec((D, D), cfg.dtype, ("embed", "mlp"), constant_init(0.0)),
        "ri": ParamSpec((D, D), cfg.dtype, ("embed", "mlp"), constant_init(0.0)),
        "rf": ParamSpec((D, D), cfg.dtype, ("embed", "mlp"), constant_init(0.0)),
        "ro": ParamSpec((D, D), cfg.dtype, ("embed", "mlp"), constant_init(0.0)),
        "up": dense_specs(D, 2 * F, e(f"{tag}/up"), axes=("embed", "mlp"),
                          dtype=cfg.dtype),
        "down": dense_specs(F, D, e(f"{tag}/down"), axes=("mlp", "embed"),
                            dtype=cfg.dtype),
    }


def _slstm_gates(params, x, cfg, ctx, tag, aux, h_prev=None):
    outs = {}
    for nm in ("wz", "wi", "wf", "wo"):
        o, a = emt_dense(params[nm], x, cfg.emt_at(f"{tag}/{nm}"), tag=f"{tag}/{nm}",
                         seed=ctx.seed, key=ctx.key)
        aux = add_aux(aux, a)
        if h_prev is not None:
            o = o + h_prev @ params["r" + nm[1]]
        outs[nm] = o.astype(jnp.float32)
    return outs, aux


def slstm(params, x, cfg: ModelConfig, *, ctx: Ctx, tag: str, state=None):
    """Returns (y, aux, new_state); state = {"c": (B,D), "n": (B,D)}."""
    B, S, D = x.shape
    aux = new_aux()

    if cfg.slstm_recurrent and S > 1:
        # exact recurrence (tests only — introduces a while loop)
        def step(carry, xt):
            c, n, h = carry
            g, _ = _slstm_gates(params, xt[:, None], cfg, ctx, tag, new_aux(),
                                h_prev=h[:, None])
            z = jnp.tanh(g["wz"][:, 0])
            i = jnp.exp(jnp.minimum(g["wi"][:, 0], 0.0))
            f = jax.nn.sigmoid(g["wf"][:, 0])
            o = jax.nn.sigmoid(g["wo"][:, 0])
            c = f * c + i * z
            n = f * n + i
            h = (o * c / jnp.maximum(n, 1.0)).astype(x.dtype)
            return (c, n, h), h
        init = (jnp.zeros((B, D), jnp.float32), jnp.zeros((B, D), jnp.float32),
                jnp.zeros((B, D), x.dtype))
        (_, _, _), hs = jax.lax.scan(step, init, x.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2)
        new_state = None
    else:
        g, aux = _slstm_gates(params, x, cfg, ctx, tag, aux)
        z = jnp.tanh(g["wz"])
        log_i = jnp.minimum(g["wi"], 0.0)
        log_f = jax.nn.log_sigmoid(g["wf"])
        o = jax.nn.sigmoid(g["wo"])
        f = jnp.exp(log_f)
        i = jnp.exp(log_i)
        c0 = None if state is None else state["c"]
        n0 = None if state is None else state["n"]
        from repro.models.mamba import _selective_scan
        if S == 1 and c0 is not None:
            c_all = (f[:, 0] * c0 + i[:, 0] * z[:, 0])[:, None]
            n_all = (f[:, 0] * n0 + i[:, 0])[:, None]
        else:
            c_all, _ = _selective_scan(f, i * z, c0)
            n_all, _ = _selective_scan(f, i, n0)
        h = (o * c_all / jnp.maximum(n_all, 1.0)).astype(x.dtype)
        new_state = {"c": c_all[:, -1], "n": n_all[:, -1]}

    up, a = emt_dense(params["up"], h, cfg.emt_at(f"{tag}/up"), tag=f"{tag}/up", seed=ctx.seed,
                      key=ctx.key)
    aux = add_aux(aux, a)
    u, gglu = jnp.split(up, 2, axis=-1)
    y, a = emt_dense(params["down"], jax.nn.gelu(gglu) * u,
                     cfg.emt_at(f"{tag}/down"), tag=f"{tag}/down",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return y, aux, new_state


def slstm_state_specs(cfg: ModelConfig, batch: int):
    return {"c": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.float32)}
