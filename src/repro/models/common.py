"""Shared model components: norms, rotary embeddings, masks, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.param import ParamSpec, ones_init, normal_init
from repro.core.emt_linear import EMTConfig, emt_dense, dense_specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_specs(d, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), jnp.float32, ("embed",), ones_init)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            }[name]


def softcap(x, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


# ---------------------------------------------------------------------------
# rotary position embeddings (default + multimodal M-RoPE)
# ---------------------------------------------------------------------------
def _rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (B, S, H, hd); positions: (B, S) int -> same shape, rotated."""
    hd = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(hd, theta), jnp.float32)       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Multimodal RoPE (qwen2-vl): positions3 (3, B, S) for (t, h, w).

    The hd/2 frequency lanes are split into `sections` groups, each rotated by its
    own position stream. For text, all three streams are equal → reduces to RoPE.
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(hd, theta), jnp.float32)        # (hd/2,)
    sec = np.asarray(sections)
    assert sec.sum() == hd // 2, (sections, hd)
    sec_id = np.repeat(np.arange(len(sections)), sec)               # (hd/2,)
    pos = positions3[sec_id]                                        # (hd/2, B, S) gathered per lane
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs      # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention masks (built from position arithmetic; fp additive)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def causal_mask(q_pos, k_pos, window: int = 0):
    """q_pos (B, Sq), k_pos (B, Sk) -> (B, 1, Sq, Sk) additive mask."""
    q = q_pos[:, None, :, None]
    k = k_pos[:, None, None, :]
    ok = k <= q
    if window and window > 0:
        ok = ok & (q - k < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def full_mask(q_valid, k_valid):
    """Bidirectional (encoder) mask from validity flags (B, S)."""
    ok = q_valid[:, None, :, None] & k_valid[:, None, None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------
def embedding_specs(vocab, d, dtype):
    return {"table": ParamSpec((vocab, d), dtype, ("vocab", "embed"),
                               normal_init(0.02))}


def embed(params, tokens, scale: bool, d: int):
    y = jnp.take(params["table"], tokens, axis=0)
    if scale:
        y = y * np.sqrt(d)
    return y


def unembed_specs(d, vocab, emt: EMTConfig, dtype):
    return dense_specs(d, vocab, emt, axes=("embed", "vocab"), dtype=dtype,
                       init=normal_init(0.02))


def unembed(params, x, emt: EMTConfig, *, tied_table=None, seed=0, key=None):
    """Project to vocabulary logits. With tied embeddings the table is reused —
    still routed through emt_dense semantics by constructing a transposed view."""
    if tied_table is not None:
        p = dict(params)
        p["w"] = tied_table.T
        y, aux = emt_dense(p, x, emt, tag="unembed", seed=seed, key=key)
        return y, aux
    return emt_dense(params, x, emt, tag="unembed", seed=seed, key=key)
