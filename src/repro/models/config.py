"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.emt_linear import EMTConfig, IDEAL
from repro.core.placement import DevicePlacement, as_placement

# block kinds that are attention layers (single source; stack.py re-exports)
ATTN_KINDS = ("attn", "global", "local")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention ---------------------------------------------------------
    rope_theta: float = 10000.0
    rope_type: str = "default"       # default | mrope
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # qwen2-vl (t, h, w) — of head_dim/2
    attn_softcap: float = 0.0        # gemma2 attention logit soft-cap
    final_softcap: float = 0.0       # gemma2 final logit soft-cap
    sliding_window: int = 0          # >0: width of local attention layers
    # per-layer block pattern, tiled/truncated to num_layers.
    # entries: "attn" | "local" | "global" | "mamba" | "mlstm" | "slstm"
    layer_pattern: Tuple[str, ...] = ("attn",)
    qk_norm: bool = False
    attn_chunk: int = 4096           # KV chunk for online-softmax long-seq path
    # Paged decode reads K/V blocks through the block table *inside* the
    # attention kernel (kernels/paged_attention.py) instead of materializing
    # the (B, logical_len) gathered view per layer per step.  Token-identical
    # at temperature 0; falls back to the gather path for mrope and when off.
    fused_paged_attn: bool = True
    # Kernel dispatch: "auto" = compiled pallas on TPU, jnp reference
    # elsewhere; "pallas" | "interpret" | "ref" force a rung of the ladder
    # (docs/kernels.md).
    paged_attn_impl: str = "auto"

    # --- moe ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # apply MoE every k-th layer (others dense MLP)
    router_aux_weight: float = 0.01

    # --- ssm (mamba) ---------------------------------------------------------
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model/16)

    # --- xlstm ----------------------------------------------------------------
    slstm_recurrent: bool = False    # True: exact R-matrix recurrence via lax.scan

    # --- encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec (seamless)

    # --- io -------------------------------------------------------------------
    input_kind: str = "tokens"       # tokens | embeds (vlm/audio frontend stubs)
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma multiplies embeddings by sqrt(d)
    norm_eps: float = 1e-6
    act: str = "silu"
    dtype: Any = jnp.bfloat16

    # --- EMT (the paper's technique) -----------------------------------------
    # Either one global EMTConfig (auto-wrapped into a zero-rule placement)
    # or a DevicePlacement mapping canonical layer paths to per-layer corners
    # (core/placement.py; docs/device_models.md).
    emt: Union[EMTConfig, DevicePlacement] = IDEAL

    # --- runtime --------------------------------------------------------------
    remat: bool = True               # jax.checkpoint around each block
    logit_dtype: Any = jnp.float32

    # -------------------------------------------------------------------------
    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def blocks(self) -> Tuple[str, ...]:
        """Resolve layer_pattern into a per-layer block-kind tuple."""
        pat = self.layer_pattern
        reps = -(-self.num_layers // len(pat))
        out = (pat * reps)[: self.num_layers]
        return tuple(out)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        """Which layers carry MoE FFN (True) vs dense FFN."""
        if self.num_experts == 0:
            return tuple(False for _ in range(self.num_layers))
        return tuple((i % self.moe_every) == (self.moe_every - 1)
                     for i in range(self.num_layers))

    # --- heterogeneous device placement --------------------------------------
    @property
    def placement(self) -> DevicePlacement:
        return as_placement(self.emt)

    def emt_at(self, path: str) -> EMTConfig:
        """Resolved per-layer EMT config for a canonical layer path."""
        return self.placement.resolve(path)

    def emt_rule_at(self, path: str) -> Optional[EMTConfig]:
        """Explicit-rule-only resolution (None unless a rule matches) — for
        sites that stay digital unless placed, e.g. the MoE router."""
        return self.placement.match(path)

    def layer_paths(self) -> Tuple[str, ...]:
        """All canonical placement paths of this model, build order."""
        attn_kinds = ATTN_KINDS
        paths = []

        def stack_paths(prefix, kinds, moe_mask, cross):
            for i, kind in enumerate(kinds):
                base = f"{prefix}/layer_{i:03d}"
                if kind in attn_kinds:
                    paths.extend(f"{base}/attn/{w}"
                                 for w in ("wq", "wk", "wv", "wo"))
                elif kind == "mamba":
                    paths.extend(f"{base}/mamba/{w}"
                                 for w in ("in", "xp", "dt", "out"))
                elif kind == "mlstm":
                    paths.extend(f"{base}/mlstm/{w}" for w in
                                 ("up", "wq", "wk", "wv", "wi", "wf", "down"))
                    continue                    # self-contained, no FFN
                elif kind == "slstm":
                    paths.extend(f"{base}/slstm/{w}" for w in
                                 ("wz", "wi", "wf", "wo", "up", "down"))
                    continue
                if cross:
                    # mirrors stack.block_specs: every non-self-contained
                    # block kind carries xattn specs in an enc-dec stack
                    paths.extend(f"{base}/xattn/{w}"
                                 for w in ("wq", "wk", "wv", "wo"))
                if moe_mask[i]:
                    paths.append(f"{base}/moe/experts")
                    paths.append(f"{base}/moe/router")
                elif self.d_ff > 0:
                    paths.extend(f"{base}/mlp/{w}" for w in ("wg", "wu", "wd"))

        if self.is_encdec:
            stack_paths("enc", tuple("attn" for _ in range(self.encoder_layers)),
                        tuple(False for _ in range(self.encoder_layers)), False)
        stack_paths("dec", self.blocks(), self.moe_layer_mask(), self.is_encdec)
        paths.append("unembed")
        return tuple(paths)

    def placement_plan(self) -> Tuple[Tuple[str, str, str], ...]:
        """Resolved (path, corner, mode) triples — the static per-layer plan.

        Router paths report what moe_specs/moe_ffn actually do: digital fp32
        unless an explicit rule places them (the default never applies)."""
        plan = []
        for p in self.layer_paths():
            if p.endswith("/moe/router"):
                hit = self.emt_rule_at(p)
                if hit is None:
                    plan.append((p, "digital", "fp32"))
                    continue
                plan.append((p, hit.corner_label, hit.mode))
            else:
                emt = self.emt_at(p)
                plan.append((p, emt.corner_label, emt.mode))
        return tuple(plan)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}
