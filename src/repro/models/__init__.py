from repro.models.config import (ModelConfig, ShapeConfig, SHAPES, TRAIN_4K,
                                 PREFILL_32K, DECODE_32K, LONG_500K)
from repro.models.context import Ctx
from repro.models import lm
