"""Paper's own model family — small VGG/ResNet-style CNNs on EMT crossbars.

Convolutions run as im2col + ``emt_dense``: each patch is the analog input-line
vector, the (k*k*Cin, Cout) kernel matrix is the crossbar — the exact mapping
described in the paper's Fig. 1(c).  Depthwise convs are intentionally *not*
special-cased (the paper's MobileNet analysis §5.1: tiny fan-in wastes peripheral
energy — our energy model reproduces that through the per-row-read term).

Normalization is LayerNorm (stateless) instead of BatchNorm — documented deviation
(DESIGN.md §8); the technique ordering claims do not depend on the norm flavor.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.emt_linear import EMTConfig, emt_dense, dense_specs, new_aux, add_aux
from repro.core.placement import DevicePlacement, as_placement
from repro.nn.param import ParamSpec, ones_init, constant_init
from repro.models.context import Ctx


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "vgg_s"
    arch: str = "vgg"                # vgg | resnet
    channels: Tuple[int, ...] = (32, 64, 128)
    blocks_per_stage: int = 1
    num_classes: int = 10
    image_size: int = 32
    in_channels: int = 3
    # one global EMTConfig or a DevicePlacement over paths s{i}b{j}/{c1,c2,proj}
    # and "head" (core/placement.py)
    emt: Union[EMTConfig, DevicePlacement] = EMTConfig()
    dtype: type = jnp.float32

    @property
    def placement(self) -> DevicePlacement:
        return as_placement(self.emt)

    def emt_at(self, path: str) -> EMTConfig:
        return self.placement.resolve(path)


def _patches(x, k, stride=1):
    """x (B,H,W,C) -> (B, H', W', k*k*C) via extract-patches (im2col)."""
    B, H, W, C = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    out = jax.lax.conv_general_dilated_patches(
        xp.transpose(0, 3, 1, 2), (k, k), (stride, stride), "VALID")
    # (B, C*k*k, H', W') -> (B, H', W', C*k*k)
    return out.transpose(0, 2, 3, 1)


def conv_specs(cin, cout, emt: EMTConfig, k=3, dtype=jnp.float32):
    return dense_specs(k * k * cin, cout, emt, axes=(None, None), dtype=dtype,
                       bias=True)


def emt_conv(params, x, emt: EMTConfig, *, k=3, stride=1, tag, ctx: Ctx):
    p = _patches(x, k, stride)
    y, aux = emt_dense(params, p, emt, tag=tag, seed=ctx.seed, key=ctx.key)
    return y, aux


def layernorm_specs(c):
    return {"scale": ParamSpec((c,), jnp.float32, (), ones_init),
            "bias": ParamSpec((c,), jnp.float32, (), constant_init(0.0))}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"]
            + params["bias"]).astype(x.dtype)


def specs(cfg: CNNConfig) -> dict:
    s = {}
    cin = cfg.in_channels
    for si, c in enumerate(cfg.channels):
        for bi in range(cfg.blocks_per_stage):
            name = f"s{si}b{bi}"
            s[name] = {"conv1": conv_specs(cin if bi == 0 else c, c,
                                           cfg.emt_at(f"{name}/c1")),
                       "ln1": layernorm_specs(c),
                       "conv2": conv_specs(c, c, cfg.emt_at(f"{name}/c2")),
                       "ln2": layernorm_specs(c)}
            if cfg.arch == "resnet" and bi == 0 and cin != c:
                s[name]["proj"] = conv_specs(cin, c,
                                             cfg.emt_at(f"{name}/proj"), k=1)
            cin = c
    s["head"] = dense_specs(cfg.channels[-1], cfg.num_classes,
                            cfg.emt_at("head"), bias=True)
    return s


def forward(params, x, cfg: CNNConfig, ctx: Ctx):
    """x: (B, H, W, C) in [0,1]. Returns (logits, aux)."""
    aux = new_aux()
    h = x.astype(cfg.dtype)
    for si, c in enumerate(cfg.channels):
        for bi in range(cfg.blocks_per_stage):
            name = f"s{si}b{bi}"
            p = params[name]
            y, a = emt_conv(p["conv1"], h, cfg.emt_at(f"{name}/c1"),
                            tag=f"{name}/c1", ctx=ctx)
            aux = add_aux(aux, a)
            y = jax.nn.relu(layernorm(p["ln1"], y))
            y2, a = emt_conv(p["conv2"], y, cfg.emt_at(f"{name}/c2"),
                             tag=f"{name}/c2", ctx=ctx)
            aux = add_aux(aux, a)
            y2 = layernorm(p["ln2"], y2)
            if cfg.arch == "resnet":
                skip = h
                if "proj" in p:
                    skip, a = emt_conv(p["proj"], h, cfg.emt_at(f"{name}/proj"),
                                       k=1, tag=f"{name}/proj", ctx=ctx)
                    aux = add_aux(aux, a)
                if skip.shape == y2.shape:
                    y2 = y2 + skip
            h = jax.nn.relu(y2)
        # 2x2 mean-pool between stages
        B, H, W, C = h.shape
        h = h.reshape(B, H // 2, 2, W // 2, 2, C).mean((2, 4))
    h = h.mean((1, 2))                                   # global average pool
    logits, a = emt_dense(params["head"], h, cfg.emt_at("head"), tag="head",
                          seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return logits.astype(jnp.float32), aux


def loss_fn(params, batch, cfg: CNNConfig, ctx: Ctx, lam: float = 0.0):
    logits, aux = forward(params, batch["images"], cfg, ctx)
    logp = jax.nn.log_softmax(logits, -1)
    ce = -jnp.mean(jnp.take_along_axis(logp, batch["labels"][:, None], -1))
    loss = ce + lam * aux["reg"]
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
    return loss, {"loss": loss, "ce": ce, "acc": acc,
                  "energy_uj": aux["energy_pj"] * 1e-6, "reg": aux["reg"],
                  "rho_mean": aux["rho_sum"] / max(1, aux["rho_layers"])}
