"""Gated (GLU) feed-forward block on EMT crossbars."""
from __future__ import annotations

from repro.core.emt_linear import emt_dense, dense_specs, new_aux, add_aux
from repro.models import common
from repro.models.config import ModelConfig
from repro.models.context import Ctx


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None, tag: str = "") -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "wg": dense_specs(D, F, cfg.emt_at(f"{tag}/wg"), axes=("embed", "mlp"),
                          dtype=cfg.dtype),
        "wu": dense_specs(D, F, cfg.emt_at(f"{tag}/wu"), axes=("embed", "mlp"),
                          dtype=cfg.dtype),
        "wd": dense_specs(F, D, cfg.emt_at(f"{tag}/wd"), axes=("mlp", "embed"),
                          dtype=cfg.dtype),
    }


def mlp(params, x, cfg: ModelConfig, *, ctx: Ctx, tag: str):
    act = common.activation(cfg.act)
    aux = new_aux()
    g, a = emt_dense(params["wg"], x, cfg.emt_at(f"{tag}/wg"), tag=f"{tag}/wg",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    u, a = emt_dense(params["wu"], x, cfg.emt_at(f"{tag}/wu"), tag=f"{tag}/wu",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    h = act(g) * u
    h = ctx.shard(h, ("batch", "seq", "mlp"))
    y, a = emt_dense(params["wd"], h, cfg.emt_at(f"{tag}/wd"), tag=f"{tag}/wd",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return y, aux
