"""GShard-style top-k mixture of experts on EMT crossbars.

Dispatch/combine use dense one-hot einsums (robust under pjit SPMD partitioning;
the gather-based variant is a documented hillclimb alternative).  Tokens are
processed in fixed-size groups so the dispatch tensor stays bounded regardless of
global batch; experts shard over the `model` mesh axis (expert parallelism).

Expert weights are (E, D, F) stacks; EMT quantization + RTN fluctuation is applied
to the whole stack through one folded 2D hash draw (see `_emt_stacked`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regularizer
from repro.core.emt_linear import (EMTConfig, new_aux, add_aux, corner_entry,
                                   emt_dense, dense_specs)
from repro.core.noise import fluctuate
from repro.core.quant import quantize_weights
from repro.nn.param import ParamSpec, fan_in_init, constant_init, normal_init
from repro.models import common
from repro.models.config import ModelConfig
from repro.models.context import Ctx

GROUP_SIZE = 2048  # tokens per dispatch group


def moe_specs(cfg: ModelConfig, tag: str = "") -> dict:
    """`tag` is the block's canonical path ("dec/layer_007/moe").  The expert
    stack resolves as one placement unit at `{tag}/experts`; the router is
    digital fp32 unless an explicit rule places it (`{tag}/router`)."""
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    emt = cfg.emt_at(f"{tag}/experts")
    r_emt = cfg.emt_rule_at(f"{tag}/router")
    if r_emt is None:
        router = {"w": ParamSpec((D, E), jnp.float32, ("embed", None),
                                 normal_init(0.02))}
    else:
        router = dense_specs(D, E, r_emt, axes=("embed", None),
                             dtype=jnp.float32, init=normal_init(0.02))
    specs = {
        "router": router,
        "wg": ParamSpec((E, D, F), cfg.dtype, ("expert", "embed", "mlp"),
                        fan_in_init(fan_axis=1)),
        "wu": ParamSpec((E, D, F), cfg.dtype, ("expert", "embed", "mlp"),
                        fan_in_init(fan_axis=1)),
        "wd": ParamSpec((E, F, D), cfg.dtype, ("expert", "mlp", "embed"),
                        fan_in_init(fan_axis=1)),
    }
    if emt.active:
        specs["rho_raw"] = ParamSpec(
            (), jnp.float32, (),
            constant_init(regularizer.rho_init_raw(emt.rho_init)))
    return specs


def _emt_stacked(w, rho, emt: EMTConfig, ctx: Ctx, tag: str):
    """Quantize + fluctuate a stacked (E, D, F) expert weight as EMT crossbars."""
    if not emt.active:
        return w
    wq, _ = quantize_weights(w, emt.quant)
    e, d, f = wq.shape
    w2 = wq.reshape(e * d, f)
    from repro.core.emt_linear import _tag_plane  # stable per-layer plane
    wn = fluctuate(w2, rho, emt.device, emt.noise,
                   key=None if ctx.key is None else jax.random.fold_in(
                       ctx.key, _tag_plane(tag)),
                   seed=ctx.seed, plane=_tag_plane(tag))
    return wn.reshape(e, d, f)


def moe_ffn(params, x, cfg: ModelConfig, *, ctx: Ctx, tag: str):
    """x: (B, S, D) -> (B, S, D). Returns (y, aux)."""
    B, S, D = x.shape
    E = cfg.num_experts
    K = cfg.experts_per_token
    F = cfg.moe_d_ff or cfg.d_ff
    T = B * S
    sg = min(GROUP_SIZE, T)
    assert T % sg == 0, (T, sg)
    G = T // sg
    cap = int(np.ceil(sg / E * cfg.capacity_factor * K))
    cap = max(4, min(sg, -(-cap // 4) * 4))

    xt = x.reshape(G, sg, D)
    xt = ctx.shard(xt, ("batch", None, "embed"))
    emt = cfg.emt_at(f"{tag}/experts")
    r_emt = cfg.emt_rule_at(f"{tag}/router")

    # --- routing (fp32; digital unless explicitly placed) -------------------
    r_aux = None
    if r_emt is None:
        logits = (xt.astype(jnp.float32) @ params["router"]["w"])    # (G, s, E)
    else:
        logits, r_aux = emt_dense(params["router"], xt.astype(jnp.float32),
                                  r_emt, tag=f"{tag}/router", seed=ctx.seed,
                                  key=ctx.key)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                     # (G, s, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # --- capacity assignment --------------------------------------------------
    # one-hot over experts per (token, k): (G, s, K, E)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # position of each (token,k) within its expert queue
    pos = jnp.cumsum(onehot.reshape(G, sg * K, E), axis=1).reshape(
        G, sg, K, E) * onehot - 1.0
    keep = (pos >= 0) & (pos < cap)
    # dispatch tensor (G, s, E, cap)
    pos_cap = jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=jnp.float32)
    disp = jnp.einsum("gske,gskec->gsec", onehot, pos_cap * keep[..., None])
    comb = jnp.einsum("gske,gskec,gsk->gsec", onehot,
                      pos_cap * keep[..., None], gate_vals)

    # --- dispatch -> experts -> combine ---------------------------------------
    disp = disp.astype(cfg.dtype)
    expert_in = jnp.einsum("gsec,gsd->gecd", disp, xt)                # (G,E,cap,D)
    expert_in = ctx.shard(expert_in, ("batch", "expert", None, "embed"))

    rho = (regularizer.rho_from_raw(params["rho_raw"])
           if emt.active else jnp.float32(1.0))
    wg = _emt_stacked(params["wg"], rho, emt, ctx, f"{tag}/wg")
    wu = _emt_stacked(params["wu"], rho, emt, ctx, f"{tag}/wu")
    wd = _emt_stacked(params["wd"], rho, emt, ctx, f"{tag}/wd")

    act = common.activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", expert_in, wg)) * \
        jnp.einsum("gecd,edf->gecf", expert_in, wu)
    h = ctx.shard(h, ("batch", "expert", None, "mlp"))
    expert_out = jnp.einsum("gecf,efd->gecd", h, wd)                  # (G,E,cap,D)

    y = jnp.einsum("gsec,gecd->gsd", comb.astype(cfg.dtype), expert_out)
    y = y.reshape(B, S, D)

    # --- aux: load-balance + z losses (fp32), EMT accounting -------------------
    aux = new_aux()
    me = jnp.mean(probs, axis=(0, 1))                                 # (E,)
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))                         # (E,)
    aux["aux_loss"] = (cfg.router_aux_weight * E * jnp.sum(me * ce)
                       + 1e-3 * jnp.mean(
                           jnp.square(jax.nn.logsumexp(logits, axis=-1))))
    if r_aux is not None:
        aux = add_aux(aux, r_aux)
    if emt.active and emt.energy_accounting != "off":
        tokens_per_expert = float(T) * K / E
        cells = 0
        for w in (wg, wu, wd):
            aux["reg"] = aux["reg"] + regularizer.layer_reg_term(
                w, rho, alpha=1.0) / w.shape[-1]
            cells += int(np.prod(w.shape))
        x_level = jax.lax.stop_gradient(jnp.mean(jnp.abs(expert_in))) * 32.0
        w_norm = jax.lax.stop_gradient(
            sum(jnp.sum(jnp.abs(w.astype(jnp.float32))) for w in (wg, wu, wd)))
        e_pj = jnp.float32(emt.device.mac_energy(
            jax.lax.stop_gradient(rho), w_norm / jnp.maximum(
                jnp.max(jnp.abs(wg)), 1e-8), x_level,
            tokens_per_expert / max(1, E)))
        reads = jnp.float32(T * K * D)
        expert_aux = new_aux()
        expert_aux.update(
            energy_pj=e_pj, reads=reads, cells=cells,
            rho_sum=jax.lax.stop_gradient(rho), rho_layers=1,
            corners={emt.corner_label: corner_entry(e_pj, reads, cells)})
        aux = add_aux(aux, expert_aux)
    return y, aux
