"""Top-level language models: decoder-only LM and encoder-decoder.

Public API (all functional):

    specs(cfg)                                  -> ParamSpec tree
    train_loss(params, batch, cfg, ctx)          -> (loss, metrics)
    prefill(params, batch, cfg, ctx, max_len)    -> (cache, last_logits, aux)
    decode_step(params, cache, tokens, cfg, ctx) -> (logits, new_cache, aux)
    init_cache_specs(cfg, batch, max_len)        -> abstract cache tree

`batch` dict: "tokens" (B,S) int32 or "embeds" (B,S,D) for vlm/audio stubs, plus
"labels" (B,S) for training; enc-dec adds "enc_embeds"/"enc_tokens".
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.emt_linear import new_aux, add_aux
from repro.core import regularizer
from repro.models import common
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models import stack as stk


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------
def specs(cfg: ModelConfig) -> dict:
    kinds = cfg.blocks()
    moe_mask = cfg.moe_layer_mask()
    s = {
        "embed": common.embedding_specs(cfg.vocab_size, cfg.d_model, cfg.dtype),
        "decoder": stk.stack_specs(cfg, cfg.num_layers, kinds, moe_mask,
                                   cross=cfg.is_encdec, tag="dec"),
        "final_norm": common.rmsnorm_specs(cfg.d_model),
    }
    head_emt = cfg.emt_at("unembed")
    if not cfg.tie_embeddings:
        s["lm_head"] = common.unembed_specs(cfg.d_model, cfg.vocab_size,
                                            head_emt, cfg.dtype)
    elif head_emt.active:
        # tied table reused as the crossbar — still needs its energy coefficient
        from repro.nn.param import ParamSpec, constant_init
        s["lm_head"] = {"rho_raw": ParamSpec(
            (), jnp.float32, (),
            constant_init(regularizer.rho_init_raw(head_emt.rho_init)))}
    if cfg.is_encdec:
        enc_kinds = tuple("attn" for _ in range(cfg.encoder_layers))
        enc_moe = tuple(False for _ in range(cfg.encoder_layers))
        s["encoder"] = stk.stack_specs(cfg, cfg.encoder_layers, enc_kinds,
                                       enc_moe, tag="enc")
        s["enc_norm"] = common.rmsnorm_specs(cfg.d_model)
    return s


# ---------------------------------------------------------------------------
# input embedding
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg: ModelConfig, ctx: Ctx):
    if cfg.input_kind == "embeds" and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = common.embed(params["embed"], batch["tokens"], cfg.embed_scale,
                         cfg.d_model)
    return ctx.shard(x, ("batch", "seq", "embed"))


def _encode(params, batch, cfg: ModelConfig, ctx: Ctx):
    """Bidirectional encoder (seamless audio stub: precomputed frame embeds)."""
    enc_x = batch.get("enc_embeds")
    if enc_x is None:
        enc_x = common.embed(params["embed"], batch["enc_tokens"],
                             cfg.embed_scale, cfg.d_model)
    enc_x = enc_x.astype(cfg.dtype)
    B, S = enc_x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    valid = jnp.ones((B, S), bool)
    mask = common.full_mask(valid, valid)
    kinds = tuple("attn" for _ in range(cfg.encoder_layers))
    moe = tuple(False for _ in range(cfg.encoder_layers))
    y, aux, _ = stk.apply_stack(params["encoder"], enc_x, cfg, kinds, moe,
                                ctx=ctx, tag="enc", positions=pos, mask=mask,
                                remat=cfg.remat)
    return common.rmsnorm(params["enc_norm"], y, cfg.norm_eps), pos, aux


def _logits(params, h, cfg: ModelConfig, ctx: Ctx):
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    p = params.get("lm_head", {})
    y, aux = common.unembed(p, h, cfg.emt_at("unembed"), tied_table=tied,
                            seed=ctx.seed, key=ctx.key)
    y = common.softcap(y.astype(cfg.logit_dtype), cfg.final_softcap)
    return y, aux


# ---------------------------------------------------------------------------
# training forward + loss
# ---------------------------------------------------------------------------
def train_loss(params, batch, cfg: ModelConfig, ctx: Ctx, lam: float = 0.0):
    x = _embed_inputs(params, batch, cfg, ctx)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    masks = {"global": common.causal_mask(pos, pos),
             "local": common.causal_mask(pos, pos, cfg.sliding_window)}

    enc_out = enc_mask = None
    aux = new_aux()
    if cfg.is_encdec:
        enc_out, enc_pos, a = _encode(params, batch, cfg, ctx)
        aux = add_aux(aux, a)
        valid = jnp.ones(enc_pos.shape, bool)
        enc_mask = common.full_mask(jnp.ones((B, S), bool), valid)

    h, a, _ = stk.apply_stack(
        params["decoder"], x, cfg, cfg.blocks(), cfg.moe_layer_mask(), ctx=ctx,
        tag="dec", positions=pos, mask=masks, enc_out=enc_out, enc_mask=enc_mask,
        remat=cfg.remat)
    aux = add_aux(aux, a)
    h = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits, a = _logits(params, h, cfg, ctx)
    aux = add_aux(aux, a)

    labels = batch["labels"]
    # Sharded-vocab-safe CE: take_along_axis over a model-sharded vocab dim
    # makes SPMD all-gather the full (B,S,V) fp32 logits (measured: +192 GB/chip
    # temps, +198 GB/chip all-reduce on gemma3-1b train_4k — EXPERIMENTS.md
    # §Perf it.1). The masked-sum form keeps every reduction local + a small
    # (B,S) all-reduce, and never materializes log_softmax.
    logits_f = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits_f, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits_f.shape,
                                          logits_f.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits_f, 0.0),
                     axis=-1)
    ce = jnp.mean(lse - picked)
    loss = ce + lam * aux["reg"] + aux["aux_loss"]
    metrics = {
        "loss": loss, "ce": ce,
        "energy_uj": aux["energy_pj"] * 1e-6,
        "reg": aux["reg"], "aux_loss": aux["aux_loss"],
        "rho_mean": aux["rho_sum"] / max(1, aux["rho_layers"]),
    }
    # per-corner energy breakdown (flat scalar keys: the train loop JSONL
    # logger floats every metric). Corner labels are static per placement.
    for name, c in aux["corners"].items():
        metrics[f"energy_uj/{name}"] = c["energy_pj"] * 1e-6
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    kinds = cfg.blocks()
    cache = {}
    for i, kind in enumerate(kinds):
        cache[f"layer_{i:03d}"] = stk.block_state_specs(
            cfg, kind, batch, max_len,
            cross_len=max_len if cfg.is_encdec else 0)
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_specs(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# paged (block-table) KV cache
# ---------------------------------------------------------------------------
def paged_lens(cfg: ModelConfig, max_len: int) -> dict:
    """Logical per-slot cache lengths for the paged layout.

    Mirrors the contiguous rule in ``stack.block_state_specs``: sliding-window
    layers hold ``min(window, max_len)`` positions; when the window does not
    shrink the cache they share the global table (``ring`` False, lens equal).
    The explicit ``ring`` flag (not lens equality) routes local layers to the
    ring table downstream — the engine clamps the global view per decode step
    (``clamped_lens``), which may transiently equal the ring length."""
    ring = min(cfg.sliding_window, max_len) if cfg.sliding_window else 0
    has_ring = bool(ring and ring < max_len and "local" in cfg.blocks())
    return {"global": max_len, "local": ring if has_ring else max_len,
            "ring": has_ring}


def clamped_lens(page_lens_full: dict, view_len: int) -> dict:
    """Length-clamp the global logical view to ``view_len`` positions.

    ``view_len`` must be block-rounded and cover every live slot's write
    position (+1); the engine buckets it to a power-of-two block count so the
    decode step recompiles O(log) times, not once per length.  Ring layers
    keep their window-sized view — only the global/cross table is clamped."""
    lens = dict(page_lens_full)
    lens["global"] = min(int(view_len), page_lens_full["global"])
    if not lens["ring"]:
        lens["local"] = lens["global"]
    return lens


def init_paged_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                           block_size: int, num_blocks: int,
                           num_ring_blocks: int = 0):
    """Abstract paged cache: attention layers become block pools of shape
    (num_blocks + 1, block_size, kv_heads, head_dim) — the extra row is the
    never-written zero block that unallocated block-table entries gather from.
    Recurrent-state layers (mamba/xlstm) keep their per-slot (batch, ...) rows.
    """
    lens = paged_lens(cfg, max_len)
    kv_shape = (cfg.num_kv_heads, cfg.head_dim)
    cache = {}
    for i, kind in enumerate(cfg.blocks()):
        name = f"layer_{i:03d}"
        if kind in stk.ATTN_KINDS:
            ring = kind == "local" and lens["ring"]
            rows = (num_ring_blocks if ring else num_blocks) + 1
            blk = {"k": jax.ShapeDtypeStruct((rows, block_size) + kv_shape,
                                             cfg.dtype),
                   "v": jax.ShapeDtypeStruct((rows, block_size) + kv_shape,
                                             cfg.dtype)}
            if cfg.is_encdec:
                xrows = num_blocks + 1       # cross K/V pages the global table
                blk["ck"] = jax.ShapeDtypeStruct(
                    (xrows, block_size) + kv_shape, cfg.dtype)
                blk["cv"] = jax.ShapeDtypeStruct(
                    (xrows, block_size) + kv_shape, cfg.dtype)
            cache[name] = blk
        else:
            cache[name] = stk.block_state_specs(cfg, kind, batch, max_len)
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     block_size: int, num_blocks: int,
                     num_ring_blocks: int = 0):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_paged_cache_specs(cfg, batch, max_len, block_size, num_blocks,
                               num_ring_blocks))


def prefill(params, batch, cfg: ModelConfig, ctx: Ctx, cache):
    """Run the prompt through the model, filling `cache`.

    Returns (new_cache, last_token_logits, aux).
    """
    x = _embed_inputs(params, batch, cfg, ctx)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    # prefill attends within the prompt (the not-yet-filled cache tail would be
    # masked anyway — attending over S instead of max_len is strictly cheaper)
    masks = {"global": common.causal_mask(pos, pos),
             "local": common.causal_mask(pos, pos, cfg.sliding_window)}

    enc_out = enc_mask = None
    aux = new_aux()
    if cfg.is_encdec:
        enc_out, enc_pos, a = _encode(params, batch, cfg, ctx)
        aux = add_aux(aux, a)
        enc_mask = common.full_mask(jnp.ones((B, S), bool),
                                    jnp.ones(enc_pos.shape, bool))

    h, a, new_caches = stk.apply_stack(
        params["decoder"], x, cfg, cfg.blocks(), cfg.moe_layer_mask(), ctx=ctx,
        tag="dec", positions=pos, mask=masks, caches=cache, cache_index=None,
        enc_out=enc_out, enc_mask=enc_mask, remat=False)
    aux = add_aux(aux, a)
    h = common.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits, a = _logits(params, h, cfg, ctx)
    aux = add_aux(aux, a)
    merged = {k: {**cache[k], **v} for k, v in new_caches.items()} if new_caches \
        else cache
    for k in cache:
        merged.setdefault(k, cache[k])
    return merged, logits[:, 0], aux


def _cache_len(cache):
    # max across layers: sliding-window layers hold ring buffers shorter than
    # the global context
    lens = [blk["k"].shape[1] for blk in cache.values() if "k" in blk]
    return max(lens) if lens else 0


def chunk_step(params, cache, tokens, start, ntok, cfg: ModelConfig, ctx: Ctx,
               active=None, page_tables=None, page_lens=None,
               all_lanes: bool = False):
    """One mixed prefill+decode step over a (B, C) token chunk.

    The continuous-batching engine admits long prompts as a stream of
    fixed-size chunks interleaved with decode: in one jitted step every batch
    row advances by ``ntok[b]`` tokens written at absolute positions
    ``start[b] .. start[b] + ntok[b] - 1`` — up to C prompt tokens for a
    prefill-phase slot, exactly one generated token for a decode-phase slot
    (the per-slot phase mask is just ``ntok``; lanes past ``ntok[b]`` are
    padding whose writes are dropped and whose query positions are clamped to
    the row's last real lane so no softmax row is ever empty).  This replaces
    the separate batch-1 power-of-two-bucketed prefill call: prompts occupy
    their *exact* positions (no left-pad) and the prefill/decode compile split
    collapses into one compile per (C, view-bucket).

    Only attention-only decoder stacks are supported (recurrent state cannot
    skip padded lanes; enc-dec needs the encoder pass) — the engine keeps the
    legacy bucketed path for those.

    Returns (last_valid_logits (B, vocab), new_cache, aux) — or, with
    ``all_lanes=True``, the full per-lane logits (B, C, vocab): the verify
    primitive of speculative decoding (serve/speculative.py), where lane j's
    logits score the draft token proposed for position ``start[b] + j + 1``.
    """
    B, C = tokens.shape
    x = common.embed(params["embed"], tokens, cfg.embed_scale, cfg.d_model)
    x = x.astype(cfg.dtype)
    start = jnp.asarray(start)
    ntok = jnp.asarray(ntok)
    j = jnp.arange(C)[None, :]
    wpos = start[:, None] + j                         # (B, C) lane positions
    qpos = start[:, None] + jnp.minimum(j, ntok[:, None] - 1)
    L = page_lens["global"] if page_lens else (_cache_len(cache) or 1)
    k_pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    # write-then-attend (non-ring layers): the chunk's own K/V lands in the
    # pools at its true positions first, so plain causal masking covers both
    # the cached history and in-chunk attention — via the chunked-prefill
    # kernel (kernels.ops.paged_prefill, causality derived from qpos
    # in-kernel) when fused, via gather + this materialized mask otherwise;
    # ring layers build their own [ring view | fresh chunk] masks
    # (attention._chunk_attend)
    masks = {"global": common.causal_mask(qpos, k_pos),
             "local": common.causal_mask(qpos, k_pos, cfg.sliding_window)}

    h, aux, new_caches = stk.apply_stack(
        params["decoder"], x, cfg, cfg.blocks(), cfg.moe_layer_mask(), ctx=ctx,
        tag="dec", positions=wpos, mask=masks, caches=cache, cache_index=start,
        remat=False, active=active, page_tables=page_tables,
        page_lens=page_lens, chunk_lens=ntok)
    if all_lanes:
        # verify mode: every lane's logits are consumed (lane j scores the
        # next-token distribution after the chunk prefix ..start+j), so the
        # unembed runs — and bills energy — over all C lanes.
        h = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits, a = _logits(params, h, cfg, ctx)
    else:
        # only each row's last real lane feeds sampling (decode rows: their
        # one token; prefill rows: the final prompt token on their last chunk)
        h_last = jnp.take_along_axis(h, (ntok - 1)[:, None, None], axis=1)
        h_last = common.rmsnorm(params["final_norm"], h_last, cfg.norm_eps)
        logits, a = _logits(params, h_last, cfg, ctx)
    aux = add_aux(aux, a)
    merged = {}
    for k in cache:
        upd = new_caches.get(k)
        merged[k] = {**cache[k], **upd} if upd else cache[k]
    return (logits if all_lanes else logits[:, 0]), merged, aux


def decode_step(params, cache, tokens, index, cfg: ModelConfig, ctx: Ctx,
                active=None, page_tables=None, page_lens=None, enc_lens=None):
    """One decode step: `tokens` (B,) generated at position `index`.

    `index` is either a scalar (lockstep: all rows at the same position) or a
    (B,) int vector (continuous batching: each slot decodes at its own
    position inside one jitted step).  `active` (B,) bool marks live slots —
    inactive rows still flow through the matmuls (SPMD batch) but their cache
    and recurrent-state rows are left untouched, so a retired slot's region
    stays frozen until the scheduler prefills a new request into it.

    `page_tables` ({"global": (B,Tg), "local": (B,Tl)} int32) + `page_lens`
    (static {"global": view_len, "local": ring_len, "ring": bool}) switch
    attention layers to the paged block-table cache layout (see
    lm.init_paged_cache).  `page_lens["global"]` is the *view length*: the
    engine clamps it (and the `Tg` table width) each step to the block-rounded
    bucket of the furthest live write position instead of max_len — masks,
    gathers, and the fused kernel's chunk walk all scale with what is actually
    resident (lm.clamped_lens).  On the fused path the step's cache write is
    folded into the attention launch (kernels.ops.paged_attention_decode:
    in-kernel scatter via input/output aliasing, inactive rows drop their
    write) — one kernel per layer per step, no separate scatter op.

    `enc_lens` (B,) int masks enc-dec cross-attention to each row's real
    encoder positions — serving engines cache ck/cv at max_len (zero-padded
    past the encoder length), and without the mask those phantom zero-K
    positions would each soak up exp(0) of softmax mass.

    Returns (logits (B, vocab), new_cache, aux).
    """
    B = tokens.shape[0]
    # modality stubs ("embeds" input kind) still decode text tokens
    x = common.embed(params["embed"], tokens[:, None], cfg.embed_scale,
                     cfg.d_model)
    x = x.astype(cfg.dtype)
    idx = jnp.asarray(index)
    if idx.ndim == 0:
        pos = jnp.broadcast_to(idx[None, None], (B, 1))
    else:
        pos = idx[:, None]                                # (B, 1) per-slot
    max_len = page_lens["global"] if page_lens else (_cache_len(cache) or 1)
    k_pos = jnp.broadcast_to(jnp.arange(max_len)[None], (B, max_len))
    masks = {"global": common.causal_mask(pos, k_pos),
             "local": common.causal_mask(pos, k_pos, cfg.sliding_window)}

    enc_mask = None
    if enc_lens is not None and cfg.is_encdec:
        valid_k = jnp.arange(max_len)[None, :] < jnp.asarray(enc_lens)[:, None]
        enc_mask = common.full_mask(jnp.ones((B, 1), bool), valid_k)

    h, aux, new_caches = stk.apply_stack(
        params["decoder"], x, cfg, cfg.blocks(), cfg.moe_layer_mask(), ctx=ctx,
        tag="dec", positions=pos, mask=masks, caches=cache, cache_index=index,
        remat=False, active=active, page_tables=page_tables,
        page_lens=page_lens, enc_mask=enc_mask)
    h = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits, a = _logits(params, h, cfg, ctx)
    aux = add_aux(aux, a)
    merged = {}
    for k in cache:
        upd = new_caches.get(k)
        merged[k] = {**cache[k], **upd} if upd else cache[k]
    return logits[:, 0], merged, aux
