"""Layer stack: builds and applies heterogeneous block sequences.

Block kinds (from ModelConfig.layer_pattern): "attn" | "global" (full causal
attention), "local" (sliding window), "mamba", "mlstm", "slstm".  Attention/mamba
blocks carry an FFN (dense GLU or MoE per `moe_layer_mask`); xLSTM blocks embed
their own projections.

Layers are python-unrolled (dict keyed "layer_NN") — DESIGN.md §7: dry-run graphs
must not contain while loops for cost/collective measurement exactness.  Remat
(jax.checkpoint) wraps each block in training mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.emt_linear import new_aux, add_aux
from repro.models import common
from repro.models.attention import attention_specs, self_attention, cross_attention
from repro.models.mlp import mlp_specs, mlp
from repro.models.moe import moe_specs, moe_ffn
from repro.models.mamba import mamba_specs, mamba, mamba_state_specs
from repro.models.xlstm import (mlstm_specs, mlstm, mlstm_state_specs,
                                slstm_specs, slstm, slstm_state_specs)
from repro.models.config import ModelConfig, ATTN_KINDS
from repro.models.context import Ctx


def block_specs(cfg: ModelConfig, kind: str, use_moe: bool,
                cross: bool = False, tag: str = "") -> dict:
    """`tag` is the block's canonical placement path ("dec/layer_007") — spec
    builders resolve the same per-layer EMT configs the apply path will."""
    specs = {"norm1": common.rmsnorm_specs(cfg.d_model)}
    if kind in ATTN_KINDS:
        specs["attn"] = attention_specs(cfg, tag=f"{tag}/attn")
    elif kind == "mamba":
        specs["mamba"] = mamba_specs(cfg, tag=f"{tag}/mamba")
    elif kind == "mlstm":
        specs["mlstm"] = mlstm_specs(cfg, tag=f"{tag}/mlstm")
        return specs                         # self-contained block
    elif kind == "slstm":
        specs["slstm"] = slstm_specs(cfg, tag=f"{tag}/slstm")
        return specs
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross:
        specs["norm_x"] = common.rmsnorm_specs(cfg.d_model)
        specs["xattn"] = attention_specs(cfg, cross=True, tag=f"{tag}/xattn")
    if cfg.d_ff > 0 or use_moe:
        specs["norm2"] = common.rmsnorm_specs(cfg.d_model)
        specs["ffn"] = moe_specs(cfg, tag=f"{tag}/moe") if use_moe \
            else mlp_specs(cfg, tag=f"{tag}/mlp")
    return specs


def block_state_specs(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      cross_len: int = 0):
    """Abstract decode-cache entries for one block."""
    if kind in ATTN_KINDS:
        # sliding-window layers keep a ring buffer of `window` slots — the
        # cache for a 32k context shrinks window/32k (64x for gemma3)
        length = max_len
        if kind == "local" and cfg.sliding_window:
            length = min(max_len, cfg.sliding_window)
        kv = {"k": jax.ShapeDtypeStruct(
                  (batch, length, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
              "v": jax.ShapeDtypeStruct(
                  (batch, length, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)}
        if cross_len:
            kv["ck"] = jax.ShapeDtypeStruct(
                (batch, cross_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)
            kv["cv"] = jax.ShapeDtypeStruct(
                (batch, cross_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)
        return kv
    if kind == "mamba":
        return mamba_state_specs(cfg, batch)
    if kind == "mlstm":
        return mlstm_state_specs(cfg, batch)
    if kind == "slstm":
        return slstm_state_specs(cfg, batch)
    raise ValueError(kind)


def _gate_state(new_state, old_state, active):
    """Freeze state rows of inactive slots (continuous-batching decode)."""
    if active is None or new_state is None or old_state is None:
        return new_state

    def sel(n, o):
        a = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o.astype(n.dtype))

    return jax.tree.map(sel, new_state, old_state)


def apply_block(params, x, cfg: ModelConfig, *, kind: str, use_moe: bool,
                tag: str, ctx: Ctx, positions=None, positions3=None, mask=None,
                cache: Optional[dict] = None, cache_index=None,
                enc_out=None, enc_mask=None, active=None, page_tables=None,
                page_lens=None, chunk_lens=None):
    """One residual block. Returns (y, aux, new_cache_or_None)."""
    aux = new_aux()
    new_cache = {}
    h = common.rmsnorm(params["norm1"], x, cfg.norm_eps)

    if chunk_lens is not None and kind not in ATTN_KINDS:
        # recurrent state advances token-by-token; a padded mixed chunk would
        # march garbage lanes through it — the engine gates these stacks onto
        # the legacy one-shot prefill path instead
        raise ValueError(
            f"chunked prefill requires an attention-only stack, got {kind!r}")
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind == "local" else 0
        m = mask["local"] if (kind == "local" and isinstance(mask, dict)) else (
            mask["global"] if isinstance(mask, dict) else mask)
        pt = pl = None
        if page_tables is not None:
            # ring layers page through the window-sized table; local layers
            # whose window >= max_len degenerate to the global table, same as
            # the contiguous cache layout rule in block_state_specs.  The
            # explicit "ring" flag (not lens equality) decides: the engine's
            # per-step view clamp can shrink the global len to the window.
            has_ring = page_lens.get("ring",
                                     page_lens["local"] != page_lens["global"])
            which = "local" if (kind == "local" and has_ring) else "global"
            pt, pl = page_tables[which], page_lens[which]
        y, a, kv = self_attention(
            params["attn"], h, cfg.replace(sliding_window=window),
            positions=positions, mask=m, ctx=ctx, tag=f"{tag}/attn",
            cache=cache, cache_index=cache_index, positions3=positions3,
            active=active, page_table=pt, page_len=pl or 0,
            page_ring=(pt is not None and which == "local"),
            chunk_lens=chunk_lens)
        aux = add_aux(aux, a)
        if kv:
            new_cache.update(kv)
        x = x + y
        if enc_out is not None or (cache is not None and "ck" in (cache or {})):
            hx = common.rmsnorm(params["norm_x"], x, cfg.norm_eps)
            xpt = page_tables["global"] if page_tables is not None else None
            xpl = page_lens["global"] if page_lens is not None else 0
            y, a, ckv = cross_attention(
                params["xattn"], hx, cfg, enc_out=enc_out, enc_mask=enc_mask,
                ctx=ctx, tag=f"{tag}/xattn", cache=cache,
                page_table=xpt, page_len=xpl)
            aux = add_aux(aux, a)
            if ckv:
                new_cache.update(ckv)
            x = x + y
    elif kind == "mamba":
        y, a, st = mamba(params["mamba"], h, cfg, ctx=ctx, tag=f"{tag}/mamba",
                         state=cache)
        aux = add_aux(aux, a)
        new_cache = _gate_state(st, cache, active)
        x = x + y
    elif kind == "mlstm":
        y, a, st = mlstm(params["mlstm"], h, cfg, ctx=ctx, tag=f"{tag}/mlstm",
                         state=cache)
        aux = add_aux(aux, a)
        return x + y, aux, _gate_state(st, cache, active)
    elif kind == "slstm":
        y, a, st = slstm(params["slstm"], h, cfg, ctx=ctx, tag=f"{tag}/slstm",
                         state=cache)
        aux = add_aux(aux, a)
        return x + y, aux, _gate_state(st, cache, active)
    else:
        raise ValueError(kind)

    if "ffn" in params:
        h = common.rmsnorm(params["norm2"], x, cfg.norm_eps)
        if use_moe:
            y, a = moe_ffn(params["ffn"], h, cfg, ctx=ctx, tag=f"{tag}/moe")
        else:
            y, a = mlp(params["ffn"], h, cfg, ctx=ctx, tag=f"{tag}/mlp")
        aux = add_aux(aux, a)
        x = x + y
    return x, aux, (new_cache or None)


def stack_specs(cfg: ModelConfig, num_layers: int, kinds, moe_mask,
                cross: bool = False, tag: str = "") -> dict:
    return {f"layer_{i:03d}": block_specs(cfg, kinds[i], moe_mask[i], cross,
                                          tag=f"{tag}/layer_{i:03d}")
            for i in range(num_layers)}


def apply_stack(params, x, cfg: ModelConfig, kinds, moe_mask, *, ctx: Ctx,
                tag: str, positions=None, positions3=None, mask=None,
                caches: Optional[dict] = None, cache_index=None,
                enc_out=None, enc_mask=None, remat: bool = False, active=None,
                page_tables=None, page_lens=None, chunk_lens=None):
    """Apply the whole stack. caches: dict layer_name -> block cache."""
    aux = new_aux()
    new_caches = {}
    lane_ok = None
    if chunk_lens is not None:
        # mixed chunk step: lanes past a row's ntok (and whole idle rows) are
        # padding whose outputs are discarded and writes dropped — but left
        # alone they would still raise the per-tensor activation (DAC)
        # quantization max and couple every real token to the padding in
        # analog mode.  Zero them between blocks: a zero lane contributes
        # zero K/V-projection writes (dropped anyway) and zero to every
        # activation max, so real lanes see exactly the statistics they
        # would in a padding-free batch.
        C = x.shape[1]
        lane_ok = jnp.arange(C)[None, :] < jnp.asarray(chunk_lens)[:, None]
        if active is not None:
            lane_ok = lane_ok & active[:, None]
        lane_ok = lane_ok[:, :, None]
        x = jnp.where(lane_ok, x, 0)
    for i, kind in enumerate(kinds):
        name = f"layer_{i:03d}"
        p = params[name]
        cache = None if caches is None else caches.get(name)

        def run(p, x, cache=cache, kind=kind, use_moe=moe_mask[i], name=name):
            return apply_block(p, x, cfg, kind=kind, use_moe=use_moe,
                               tag=f"{tag}/{name}", ctx=ctx, positions=positions,
                               positions3=positions3, mask=mask, cache=cache,
                               cache_index=cache_index, enc_out=enc_out,
                               enc_mask=enc_mask, active=active,
                               page_tables=page_tables, page_lens=page_lens,
                               chunk_lens=chunk_lens)

        if remat:
            x, a, upd = jax.checkpoint(
                lambda p, x: run(p, x), static_argnums=())(p, x)
        else:
            x, a, upd = run(p, x)
        aux = add_aux(aux, a)
        if upd is not None:
            new_caches[name] = upd
        if lane_ok is not None:
            x = jnp.where(lane_ok, x, 0)
        x = ctx.shard(x, ("batch", "seq", "embed"))
    return x, aux, new_caches
