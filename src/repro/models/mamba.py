"""Mamba selective-SSM block (jamba's sequence mixer), TPU-native.

The selective scan is a linear recurrence  h_t = dA_t * h_{t-1} + dBx_t  computed
with `jax.lax.associative_scan` (log-depth, no `while` loops — keeps dry-run graphs
exactly measurable, and is the S5-style TPU-idiomatic formulation).  Long sequences
are processed in fixed chunks (python-unrolled) so the (B, S, d_inner, N) state
tensor stays bounded.

EMT: in/x/dt/out projections are crossbar matmuls; the depthwise conv and the
recurrence itself are not stored-weight MACs (see DESIGN.md §Arch-applicability)
and run ideal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.emt_linear import emt_dense, dense_specs, new_aux, add_aux
from repro.nn.param import ParamSpec, constant_init, normal_init
from repro.models.config import ModelConfig
from repro.models.context import Ctx

SCAN_CHUNK = 4096


def mamba_specs(cfg: ModelConfig, tag: str = "") -> dict:
    """`tag` is the block's canonical path ("dec/layer_007/mamba"); projection
    paths use the apply-time suffixes in/xp/dt/out."""
    D, DI, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank

    def a_init(key, shape, dtype):
        # S4D-real init: A = -(1..N) per channel
        a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (DI, 1))
        return jnp.log(a).astype(dtype)

    return {
        "in_proj": dense_specs(D, 2 * DI, cfg.emt_at(f"{tag}/in"),
                               axes=("embed", "mlp"), dtype=cfg.dtype),
        "conv_w": ParamSpec((cfg.ssm_conv, DI), cfg.dtype, (None, "mlp"),
                            normal_init(0.1)),
        "conv_b": ParamSpec((DI,), cfg.dtype, ("mlp",), constant_init(0.0)),
        "x_proj": dense_specs(DI, R + 2 * N, cfg.emt_at(f"{tag}/xp"),
                              axes=("mlp", None), dtype=cfg.dtype),
        "dt_proj": dense_specs(R, DI, cfg.emt_at(f"{tag}/dt"),
                               axes=(None, "mlp"), dtype=cfg.dtype, bias=True),
        "A_log": ParamSpec((DI, N), jnp.float32, ("mlp", None), a_init),
        "D_skip": ParamSpec((DI,), jnp.float32, ("mlp",), constant_init(1.0)),
        "out_proj": dense_specs(DI, D, cfg.emt_at(f"{tag}/out"),
                                axes=("mlp", "embed"), dtype=cfg.dtype),
    }


def _causal_depthwise_conv(x, w, b, state=None):
    """x (B, S, DI), w (K, DI). state: (B, K-1, DI) carried context (decode)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return y + b, new_state


def _ssm_combine(left, right):
    al, bl = left
    ar, br = right
    return al * ar, ar * bl + br


def _selective_scan(dA, dBx, h0=None, chunk=SCAN_CHUNK):
    """h_t = dA_t * h_{t-1} + dBx_t over axis=1. Returns (h_all, h_last)."""
    B, S = dA.shape[:2]
    chunk = min(chunk, S)
    outs = []
    h_prev = h0
    for s0 in range(0, S, chunk):
        a = dA[:, s0:s0 + chunk]
        b = dBx[:, s0:s0 + chunk]
        a_cum, local = jax.lax.associative_scan(_ssm_combine, (a, b), axis=1)
        h = local if h_prev is None else a_cum * h_prev[:, None] + local
        outs.append(h)
        h_prev = h[:, -1]
    return jnp.concatenate(outs, axis=1), h_prev


def mamba(params, x, cfg: ModelConfig, *, ctx: Ctx, tag: str, state=None):
    """Full-sequence mixing. state (decode): {"h": (B,DI,N), "conv": (B,K-1,DI)}.

    Returns (y, aux, new_state). For S==1 with a state, performs one recurrent step.
    """
    B, S, D = x.shape
    DI, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    aux = new_aux()

    xz, a = emt_dense(params["in_proj"], x, cfg.emt_at(f"{tag}/in"), tag=f"{tag}/in",
                      seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = ctx.shard(x_in, ("batch", "seq", "mlp"))

    conv_state = None if state is None else state["conv"]
    x_c, new_conv = _causal_depthwise_conv(x_in, params["conv_w"],
                                           params["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    xdb, a = emt_dense(params["x_proj"], x_c, cfg.emt_at(f"{tag}/xp"), tag=f"{tag}/xp",
                       seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    dt_r, Bm, Cm = jnp.split(xdb, [R, R + N], axis=-1)
    dt, a = emt_dense(params["dt_proj"], dt_r, cfg.emt_at(f"{tag}/dt"), tag=f"{tag}/dt",
                      seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    dt = jax.nn.softplus(dt.astype(jnp.float32))                     # (B,S,DI)

    A = -jnp.exp(params["A_log"])                                    # (DI,N)
    dA = jnp.exp(dt[..., None] * A)                                  # (B,S,DI,N)
    dBx = (dt * x_c.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]                        # (B,S,DI,N)

    h0 = None if state is None else state["h"]
    if S == 1 and h0 is not None:
        h_last = dA[:, 0] * h0 + dBx[:, 0]
        h_all = h_last[:, None]
    else:
        h_all, h_last = _selective_scan(dA, dBx, h0)

    y = jnp.einsum("bsdn,bsn->bsd", h_all, Cm.astype(jnp.float32))
    y = y + params["D_skip"] * x_c.astype(jnp.float32)
    y = (y.astype(cfg.dtype)) * jax.nn.silu(z)
    out, a = emt_dense(params["out_proj"], y, cfg.emt_at(f"{tag}/out"), tag=f"{tag}/out",
                       seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    new_state = {"h": h_last, "conv": new_conv}
    return out, aux, new_state


def mamba_state_specs(cfg: ModelConfig, batch: int):
    """Abstract decode-state shapes for cache allocation."""
    return {
        "h": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, cfg.d_inner),
                                     cfg.dtype),
    }
