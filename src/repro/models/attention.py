"""Grouped-query attention with RoPE/M-RoPE, soft-capping, sliding windows,
KV caches, and cross-attention — every projection an EMT crossbar matmul."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emt_linear import emt_dense, dense_specs, new_aux, add_aux
from repro.models import common
from repro.models.config import ModelConfig, ATTN_KINDS
from repro.models.context import Ctx


def attention_specs(cfg: ModelConfig, cross: bool = False, tag: str = "") -> dict:
    """`tag` is the block's canonical path (e.g. "dec/layer_007/attn") — each
    projection resolves its own EMT corner through the placement."""
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": dense_specs(D, H * hd, cfg.emt_at(f"{tag}/wq"),
                          axes=("embed", "heads"), dtype=cfg.dtype),
        "wk": dense_specs(D, KV * hd, cfg.emt_at(f"{tag}/wk"),
                          axes=("embed", "heads"), dtype=cfg.dtype),
        "wv": dense_specs(D, KV * hd, cfg.emt_at(f"{tag}/wv"),
                          axes=("embed", "heads"), dtype=cfg.dtype),
        "wo": dense_specs(H * hd, D, cfg.emt_at(f"{tag}/wo"),
                          axes=("heads", "embed"), dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        specs["qnorm"] = common.rmsnorm_specs(hd)
        specs["knorm"] = common.rmsnorm_specs(hd)
    return specs


def _project_qkv(params, xq, xkv, cfg: ModelConfig, ctx: Ctx, tag: str):
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    aux = new_aux()
    q, a = emt_dense(params["wq"], xq, cfg.emt_at(f"{tag}/wq"), tag=f"{tag}/wq",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    k, a = emt_dense(params["wk"], xkv, cfg.emt_at(f"{tag}/wk"), tag=f"{tag}/wk",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    v, a = emt_dense(params["wv"], xkv, cfg.emt_at(f"{tag}/wv"), tag=f"{tag}/wv",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    q = q.reshape(*xq.shape[:-1], H, hd)
    k = k.reshape(*xkv.shape[:-1], KV, hd)
    v = v.reshape(*xkv.shape[:-1], KV, hd)
    if cfg.qk_norm:
        q = common.rmsnorm(params["qnorm"], q, cfg.norm_eps)
        k = common.rmsnorm(params["knorm"], k, cfg.norm_eps)
    return q, k, v, aux


def _gqa_core(q, k, v, mask, cfg: ModelConfig, ctx: Ctx):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask (B,1,Sq,Sk) additive fp32.

    Long sequences (Sq>1 and Sk>attn_chunk) run the chunked online-softmax
    ("flash-style") path: KV is consumed in fixed chunks with running
    (max, sum, acc) statistics — scores for a 32k x 32k prefill never
    materialize (34 GB/chip -> ~chunk-sized transients).  Python-unrolled:
    dry-run graphs stay loop-free.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    q = ctx.shard(q, ("batch", "seq", "heads", None))
    # K/V stay in cache dtype (bf16): upcasting a 32k cache to fp32 per layer
    # doubles+ decode HBM traffic (§Perf cell-C it.2). The score einsum
    # accumulates in fp32 via preferred_element_type (MXU-native).
    qg = q.reshape(B, Sq, KV, G, hd)
    chunk = cfg.attn_chunk

    def scores_of(kc):
        return jnp.einsum("bqkgh,bskh->bkgqs", qg, kc,
                          preferred_element_type=jnp.float32) / np.sqrt(hd)

    if Sq == 1 or not chunk or Sk <= chunk:
        scores = scores_of(k)
        scores = common.softcap(scores, cfg.attn_softcap)
        if mask is not None:   # None => attend everywhere (cross-attn at decode)
            scores = scores + mask.reshape(B, 1, 1, Sq, -1)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Sq, H * hd).astype(v.dtype)

    # chunked online softmax over Sk
    m = jnp.full((B, KV, G, Sq), -1e30, jnp.float32)
    l = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    for c0 in range(0, Sk, chunk):
        kc = k[:, c0:c0 + chunk]
        vc = v[:, c0:c0 + chunk]
        s = scores_of(kc)
        s = common.softcap(s, cfg.attn_softcap)
        if mask is not None:
            s = s + mask[:, :, :, c0:c0 + chunk].reshape(B, 1, 1, Sq, -1)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(s > common.NEG_INF / 2,
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, KV, G, Sq, hd)
    out = out.transpose(0, 3, 1, 2, 4)                # -> (B, Sq, KV, G, hd)
    return out.reshape(B, Sq, H * hd).astype(v.dtype)


def _fused_paged_ok(cfg: ModelConfig) -> bool:
    """Whether the fused paged-attention kernels serve this config's paged
    attention (decode and chunked prefill).

    The only fallback left is the explicit kill switch
    (``cfg.fused_paged_attn=False``).  M-RoPE configs (qwen2_vl) used to fall
    back too, but the kernel only ever consumes *post*-RoPE q/k and causal
    mask rows over token indices — the multimodal position streams are
    applied before the cache write, so the mask-row plumbing is
    position-stream-agnostic and mrope decode runs the fused path like
    everyone else (tests/test_paged_attention.py proves token identity)."""
    return bool(cfg.fused_paged_attn)


def _paged_impl(cfg: ModelConfig) -> str:
    from repro.kernels import ops as kops     # lazy: kernels depend on core
    if cfg.paged_attn_impl != "auto":
        return cfg.paged_attn_impl
    return kops.default_paged_impl()


def paged_attn_plan(cfg: ModelConfig):
    """Static per-layer decode-attention path resolution for the paged cache.

    Returns (layer_path, resolution) rows — what `launch/serve.py` prints at
    startup so an operator can see which layers hit the fused kernel (and on
    which rung of the dispatch ladder) vs the gather fallback, and why.
    """
    if not cfg.fused_paged_attn:
        res = "gather fallback (fused_paged_attn=False)"
    else:
        res = f"fused paged kernel [{_paged_impl(cfg)}]"
    rows = []
    for i, kind in enumerate(cfg.blocks()):
        if kind not in ATTN_KINDS:
            continue
        rows.append((f"dec/layer_{i:03d}/attn ({kind})", res))
        if cfg.is_encdec:
            rows.append((f"dec/layer_{i:03d}/xattn (cross)", res))
    return rows


def _fused_paged_attend(q, k_pool, v_pool, table, mask_rows, cfg: ModelConfig):
    """Dispatch one decode step to the fused kernel.

    q (B, 1, H, hd) post-RoPE; pools (num_blocks + 1, bs, KV, hd); table
    (B, T) int32; mask_rows (B, L) additive fp32 over logical positions.
    Returns (B, 1, H*hd) in cache dtype — same contract as `_gqa_core`.
    """
    from repro.kernels import ops as kops
    B, Sq, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    out = kops.paged_attention(
        q[:, 0].reshape(B, KV, G, hd), k_pool, v_pool, table, mask_rows,
        softcap=float(cfg.attn_softcap or 0.0), impl=_paged_impl(cfg))
    return out.reshape(B, 1, H * hd).astype(k_pool.dtype)


def _fused_paged_decode(q, cache, table, mask_rows, k_new, v_new, wpos,
                        active, cfg: ModelConfig):
    """ONE kernel launch per decode layer: the step's new K/V rows are
    scattered through the block table *inside* the kernel that reads them
    (input_output_aliases pins the pool update in place), replacing the
    scatter + gather/attend pair.  Same shape contract as
    `_fused_paged_attend` plus the write operands; returns (y, new_cache).
    """
    from repro.kernels import ops as kops
    B, Sq, H, hd = q.shape
    KV = cache["k"].shape[2]
    G = H // KV
    out, k_pool, v_pool = kops.paged_attention_decode(
        q[:, 0].reshape(B, KV, G, hd), cache["k"], cache["v"], table,
        mask_rows, k_new, v_new, wpos, active,
        softcap=float(cfg.attn_softcap or 0.0), impl=_paged_impl(cfg))
    y = out.reshape(B, 1, H * hd).astype(k_pool.dtype)
    return y, {"k": k_pool, "v": v_pool}


def _visible_kv_elems(mask, kv_heads: int, head_dim: int):
    """K/V cache elements a decode step actually reads: mask-visible logical
    positions x kv heads x head_dim x 2 (K and V).  Masked positions (NEG_INF
    lanes — clamped tails, causally-hidden positions, unwritten ring slots)
    are not reads and must not be billed.  Mask-VISIBLE positions are billed
    even when they resolve to the zero block (e.g. an idle row's position 0):
    the engine issues that read, mirroring the energy model's idle-row
    accounting (engine docstring: idle reads are real, booked as waste)."""
    vis = jnp.sum((mask > common.NEG_INF / 2).astype(jnp.float32))
    return vis * jnp.float32(kv_heads * head_dim * 2)


def _visible_chunk_kv_elems(mask, valid, kv_heads: int, head_dim: int):
    """Chunk-step K/V read billing: mask-visible positions of *real* lanes.

    The chunk mask is (B, 1, C, L) with one row per query lane, and padding
    lanes (j >= ntok[b]) carry a duplicate of the row's last real lane (qpos
    is clamped so no softmax row is empty) — those lanes are compute filler,
    not cache reads, and billing them over-counted every partially-filled
    chunk by (C - ntok) x visible.  Weight by the (B, C) `valid` lane mask:
    identical for the flash prefill kernel and the legacy gather path (both
    see the same real lanes), and consistent with decode's per-row billing
    (`_visible_kv_elems`): an idle decode-phase row still bills its one
    clamped lane — idle reads are real, booked as waste (engine docstring).
    """
    vis = (mask > common.NEG_INF / 2).astype(jnp.float32)
    vis = vis * valid[:, None, :, None].astype(jnp.float32)
    return jnp.sum(vis) * jnp.float32(kv_heads * head_dim * 2)


def paged_gather(pool, table, length: int):
    """Gather a (B, length, ...) logical view out of a block pool.

    `pool` is (num_blocks + 1, block_size, ...) with the zero block last;
    `table` is (B, T) int32 block ids (unallocated entries -> zero block), so
    logical position j of row b reads pool[table[b, j // bs], j % bs] — exact
    zeros wherever nothing was written, bit-identical to a contiguous cache.
    """
    bs = pool.shape[1]
    j = jnp.arange(length)
    return pool[table[:, j // bs], (j % bs)[None, :]]


def _paged_write(pool, table, wpos, val, active):
    """Scatter one token per row into its block: row b writes
    pool[table[b, wpos[b] // bs], wpos[b] % bs]. Inactive rows are redirected
    out of bounds and dropped (their blocks may already be recycled)."""
    bs = pool.shape[1]
    blk = jnp.take_along_axis(table, (wpos // bs)[:, None], axis=1)[:, 0]
    if active is not None:
        blk = jnp.where(active, blk, pool.shape[0])
    return pool.at[blk, jnp.mod(wpos, bs)].set(val.astype(pool.dtype),
                                               mode="drop")


def _chunk_write(cache_kv, wpos, val, write_ok, page_table=None, ring_len=0):
    """Scatter a (B, C) chunk of per-position K or V rows into the cache.

    `wpos` (B, C) are absolute write positions, `write_ok` (B, C) marks lanes
    that really write (valid token, active slot, ring last-writer) — dropped
    lanes are redirected out of bounds.  Contiguous caches index (row, pos);
    paged caches resolve (block, offset) through `page_table`.  Ring caches
    pass `ring_len` and the caller pre-wraps positions."""
    if page_table is not None:
        bs = cache_kv.shape[1]
        blk = jnp.take_along_axis(page_table, wpos // bs, axis=1)
        blk = jnp.where(write_ok, blk, cache_kv.shape[0])       # OOB: dropped
        return cache_kv.at[blk, wpos % bs].set(val.astype(cache_kv.dtype),
                                               mode="drop")
    B = wpos.shape[0]
    rows = jnp.arange(B)[:, None]
    idx = jnp.where(write_ok, wpos, cache_kv.shape[1])          # OOB: dropped
    return cache_kv.at[rows, idx].set(val.astype(cache_kv.dtype), mode="drop")


def _chunk_attend(q, k, v, cache, mask, *, start, ntok, positions, active,
                  page_table, page_len: int, ring: bool, win: int,
                  cfg: ModelConfig, ctx: Ctx):
    """Chunked mixed prefill+decode cache update + attention for one layer.

    Each batch row processes `ntok[b]` real tokens (1 for decode-phase slots,
    up to C for prefill-phase slots) at absolute positions
    ``start[b] .. start[b] + ntok[b] - 1``; the remaining lanes are padding
    (writes dropped, query outputs discarded by the caller).

    * global / non-ring layers: write-then-attend — all chunk K/V land in
      the cache first, then the row attends everything visible.  Paged
      caches (default) dispatch the flash-style prefill kernel
      (`kernels.ops.paged_prefill`): table-resolved pool tiles with
      qpos-derived causality, no materialized view; the kill-switch fallback
      (and contiguous caches) gather the logical view and attend through the
      caller's causal mask.  A decode row (ntok == 1) sees *exactly* the
      layout of the pure decode step either way.
    * ring layers: chunk writes can overwrite window positions an earlier
      in-chunk query still needs, so the row attends ``[pre-write ring view |
      fresh chunk K/V]`` with ring position masks; only the final ``win``
      lanes of the chunk are written (last-writer-wins).

    Returns (y, new_cache, kv_read_elems).
    """
    B, C = positions.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    j = jnp.arange(C)[None, :]
    valid = j < ntok[:, None]                                   # (B, C)
    qj = jnp.minimum(j, ntok[:, None] - 1)                      # clamped lane
    qpos = start[:, None] + qj
    write_ok = valid
    if active is not None:
        write_ok = write_ok & active[:, None]

    if not ring:
        wpos = positions
        k_cache = _chunk_write(cache["k"], wpos, k, write_ok, page_table)
        v_cache = _chunk_write(cache["v"], wpos, v, write_ok, page_table)
        new_cache = {"k": k_cache, "v": v_cache}
        # real lanes' mask-visible positions only (padding lanes carry
        # clamped duplicate rows — compute filler, not cache reads)
        kv_reads = _visible_chunk_kv_elems(mask, valid, KV, hd)
        if page_table is not None and _fused_paged_ok(cfg):
            # flash-style prefill kernel: the chunk's K/V is already in the
            # pool (write-then-attend, same ordering as the gather path), the
            # kernel walks table-resolved tiles with qpos-derived causality —
            # the (B, page_len, KV, hd) view never materializes
            from repro.kernels import ops as kops
            y = kops.paged_prefill(q, k_cache, v_cache, page_table, qpos,
                                   softcap=float(cfg.attn_softcap or 0.0),
                                   impl=_paged_impl(cfg))
            return y.astype(k_cache.dtype), new_cache, kv_reads
        if page_table is not None:
            k_att = paged_gather(k_cache, page_table, page_len)
            v_att = paged_gather(v_cache, page_table, page_len)
        else:
            k_att, v_att = k_cache, v_cache
        # caller's mask already covers the logical view at the clamped qpos
        return (_gqa_core(q, k_att, v_att, mask, cfg, ctx), new_cache,
                kv_reads)

    # --- ring layer: [old ring view | fresh chunk] with position masks ------
    wpos = jnp.mod(positions, win)
    # last-writer-wins: of the chunk lanes mapping to one ring slot only the
    # final one may write (scatter order over duplicates is unspecified)
    write_ok = write_ok & (j >= ntok[:, None] - win)
    k_old = (paged_gather(cache["k"], page_table, win)
             if page_table is not None else cache["k"])
    v_old = (paged_gather(cache["v"], page_table, win)
             if page_table is not None else cache["v"])
    new_cache = {"k": _chunk_write(cache["k"], wpos, k, write_ok, page_table),
                 "v": _chunk_write(cache["v"], wpos, v, write_ok, page_table)}
    # pre-chunk ring slot s holds position p(s) = last - ((last - s) mod win)
    # for last = start - 1 (start == 0 -> all negative -> masked)
    last = (start - 1)[:, None]
    p_old = last - jnp.mod(last - jnp.arange(win)[None, :], win)   # (B, win)
    ok_old = (p_old[:, None, :] >= 0) & \
             (qpos[:, :, None] - p_old[:, None, :] < win)          # (B, C, win)
    # in-chunk lane i visible to query lane j: causal and within the window
    i = jnp.arange(C)[None, None, :]
    ok_new = (i <= qj[:, :, None]) & (qj[:, :, None] - i < win)    # (B, C, C)
    mask_cat = jnp.where(jnp.concatenate([ok_old, ok_new], axis=-1),
                         0.0, common.NEG_INF).astype(jnp.float32)
    mask_cat = mask_cat[:, None]                                   # (B,1,C,·)
    k_att = jnp.concatenate([k_old, k.astype(k_old.dtype)], axis=1)
    v_att = jnp.concatenate([v_old, v.astype(v_old.dtype)], axis=1)
    kv_reads = _visible_chunk_kv_elems(mask_cat, valid, KV, hd)
    return _gqa_core(q, k_att, v_att, mask_cat, cfg, ctx), new_cache, kv_reads


def self_attention(params, x, cfg: ModelConfig, *, positions, mask, ctx: Ctx,
                   tag: str, cache: Optional[dict] = None, cache_index=None,
                   positions3=None, active=None, page_table=None,
                   page_len: int = 0, page_ring: Optional[bool] = None,
                   chunk_lens=None):
    """Self-attention. Train/prefill: full-sequence. Decode: one step vs cache.

    `cache_index` is a scalar (lockstep decode: every row at the same position)
    or a (B,) int vector (continuous batching: each slot at its own position).
    `active` (B,) bool gates cache writes in the vector path — retired slots'
    cache regions stay frozen until the scheduler re-prefills them.

    With `page_table` (B, T) int32 + `page_len` the decode cache is paged: the
    layer's cache entries are block pools and reads/writes go through the
    block table (`page_len` is the logical per-slot length — the engine's
    clamped view for global layers, the window for ring layers).  `page_ring`
    says whether the table is the window-sized ring table (modular writes +
    ring position masks) — the caller's layout decision, threaded from
    `stack.apply_block`; when None (direct callers) it is inferred from
    `page_len == window`, which is only safe while views are unclamped.

    `chunk_lens` (B,) int switches to the chunked mixed prefill+decode path
    (`lm.chunk_step`): `x` carries a (B, C) chunk per row of which only the
    first ``chunk_lens[b]`` lanes are real — prefill-phase rows stream their
    prompt in fixed-size chunks while decode-phase rows ride along with one
    token (see `_chunk_attend`).  `cache_index` is then the per-row start
    position and `positions` the (B, C) absolute lane positions.

    Returns (y, aux, new_cache_entries_or_None).
    """
    q, k, v, aux = _project_qkv(params, x, x, cfg, ctx, tag)

    if cfg.rope_type == "mrope":
        p3 = positions3 if positions3 is not None else jnp.broadcast_to(
            positions[None], (3, *positions.shape))
        q = common.apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
        k = common.apply_mrope(k, p3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    fused_y = None
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if cache is not None:
        win = cfg.sliding_window
        ring = bool(win) and cache["k"].shape[1] == win
        B = x.shape[0]
        if cache_index is None:
            # ---- prefill: fill the cache, attend within the prompt ----------
            S = k.shape[1]
            if ring and S >= win:
                # ring buffer keeps the last `win` prompt tokens at slots
                # (pos mod win) — i.e. the tail, cyclically shifted
                shift = (S - win) % win
                k_cache = jnp.roll(k[:, S - win:], shift, axis=1)
                v_cache = jnp.roll(v[:, S - win:], shift, axis=1)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": k_cache.astype(cache["k"].dtype),
                         "v": v_cache.astype(cache["v"].dtype)}
            # fall through: attend with the prompt-length k, v + caller's mask
        elif chunk_lens is not None:
            # ---- chunked mixed prefill+decode: per-row token chunks ---------
            idx = jnp.asarray(cache_index)
            ring_here = bool(page_ring) if page_table is not None else ring
            y, new_cache, reads = _chunk_attend(
                q, k, v, cache, mask, start=idx, ntok=jnp.asarray(chunk_lens),
                positions=positions, active=active, page_table=page_table,
                page_len=page_len, ring=ring_here, win=win, cfg=cfg, ctx=ctx)
            aux["kv_reads"] = aux["kv_reads"] + reads
            o, a = emt_dense(params["wo"], y, cfg.emt_at(f"{tag}/wo"),
                             tag=f"{tag}/wo", seed=ctx.seed, key=ctx.key)
            aux = add_aux(aux, a)
            return o, aux, new_cache
        elif page_table is not None:
            # ---- decode, paged: fused kernel (default) writes the token's
            # K/V through the block table AND walks the pool tiles inside one
            # launch; the fallback scatters first, then gathers the
            # (B, page_len) logical view (already length-clamped by the
            # engine to the live block-rounded bucket, not max_len) ---------
            idx = jnp.asarray(cache_index)
            if idx.ndim == 0:                 # lockstep scalar index
                idx = jnp.broadcast_to(idx, (B,))
            L = page_len
            ring_paged = page_ring if page_ring is not None \
                else bool(win) and L == win
            wpos = jnp.mod(idx, L) if ring_paged else idx
            if ring_paged:
                # same modular position arithmetic as the contiguous ring
                k_pos = idx[:, None] - jnp.mod(
                    idx[:, None] - jnp.arange(L)[None, :], L)      # (B, L)
                mask_rows = jnp.where(k_pos >= 0, 0.0,
                                      common.NEG_INF).astype(jnp.float32)
            else:
                # caller's mask already covers the logical length L
                mask_rows = mask.reshape(B, L)
            aux["kv_reads"] = aux["kv_reads"] + _visible_kv_elems(
                mask_rows, KV, hd)
            if _fused_paged_ok(cfg):
                # one launch: in-kernel cache write + chunk-walk attend
                fused_y, new_cache = _fused_paged_decode(
                    q, cache, page_table, mask_rows, k[:, 0], v[:, 0],
                    wpos, active, cfg)
            else:
                k_cache = _paged_write(cache["k"], page_table, wpos,
                                       k[:, 0], active)
                v_cache = _paged_write(cache["v"], page_table, wpos,
                                       v[:, 0], active)
                new_cache = {"k": k_cache, "v": v_cache}
                k = paged_gather(k_cache, page_table, L)
                v = paged_gather(v_cache, page_table, L)
                mask = jnp.broadcast_to(mask_rows[:, None, None, :],
                                        (B, 1, 1, L))
        elif ring:
            # ---- decode, sliding-window layer: ring write + ring attend -----
            # A 32k-cache local layer reads `win` keys, not 32768, and its
            # cache is win-sized. (A windowed dynamic_slice of a seq-sharded
            # full cache was measured strictly WORSE — SPMD all-gathers the
            # cache; see EXPERIMENTS.md §Perf "windowed decode".)
            idx = jnp.asarray(cache_index)
            if idx.ndim == 0:
                slot = jnp.mod(idx, win)
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
                # slot s holds position p(s) = index - ((index - s) mod win)
                k_pos = (idx - jnp.mod(idx - jnp.arange(win), win))[None]
            else:
                # per-slot ring write; inactive rows write out-of-bounds and
                # are dropped, freezing their cache region
                slot = jnp.mod(idx, win)
                if active is not None:
                    slot = jnp.where(active, slot, win)
                rows = jnp.arange(B)
                k_cache = cache["k"].at[rows, slot].set(
                    k[:, 0].astype(cache["k"].dtype), mode="drop")
                v_cache = cache["v"].at[rows, slot].set(
                    v[:, 0].astype(cache["v"].dtype), mode="drop")
                k_pos = idx[:, None] - jnp.mod(
                    idx[:, None] - jnp.arange(win)[None, :], win)   # (B, win)
            mask = jnp.broadcast_to(
                jnp.where(k_pos >= 0, 0.0, common.NEG_INF)[:, None, None, :],
                (B, 1, 1, win))
            aux["kv_reads"] = aux["kv_reads"] + _visible_kv_elems(mask, KV, hd)
            new_cache = {"k": k_cache, "v": v_cache}
            k, v = k_cache, v_cache
        else:
            # ---- decode, global layer: write at cache_index, attend all -----
            idx = jnp.asarray(cache_index)
            if idx.ndim == 0:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
            else:
                write_idx = idx
                if active is not None:
                    write_idx = jnp.where(active, idx, cache["k"].shape[1])
                rows = jnp.arange(B)
                k_cache = cache["k"].at[rows, write_idx].set(
                    k[:, 0].astype(cache["k"].dtype), mode="drop")
                v_cache = cache["v"].at[rows, write_idx].set(
                    v[:, 0].astype(cache["v"].dtype), mode="drop")
            if mask is not None:
                aux["kv_reads"] = aux["kv_reads"] + _visible_kv_elems(
                    mask, KV, hd)
            new_cache = {"k": k_cache, "v": v_cache}
            k, v = k_cache, v_cache

    y = fused_y if fused_y is not None else _gqa_core(q, k, v, mask, cfg, ctx)
    o, a = emt_dense(params["wo"], y, cfg.emt_at(f"{tag}/wo"), tag=f"{tag}/wo",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return o, aux, new_cache


def cross_attention(params, x, cfg: ModelConfig, *, enc_out=None, enc_mask=None,
                    ctx: Ctx, tag: str, cache: Optional[dict] = None,
                    page_table=None, page_len: int = 0):
    """Encoder-decoder cross attention. K/V from `enc_out` (prefill) or `cache`.

    With `page_table`/`page_len` the decode read gathers the encoder K/V
    through the block table (cross K/V is written once at prefill insert and
    never appended, so the table is read-only here)."""
    aux = new_aux()
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, a = emt_dense(params["wq"], x, cfg.emt_at(f"{tag}/wq"), tag=f"{tag}/wq",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    q = q.reshape(*x.shape[:-1], H, hd)
    fused_y = None
    if enc_out is None and cache is not None and "ck" in cache:
        B = x.shape[0]
        if page_table is not None:
            L = page_len
            mask_rows = (enc_mask.reshape(B, L) if enc_mask is not None
                         else jnp.zeros((B, L), jnp.float32))
            aux["kv_reads"] = aux["kv_reads"] + _visible_kv_elems(
                mask_rows, KV, hd)
            if _fused_paged_ok(cfg):
                fused_y = _fused_paged_attend(q, cache["ck"], cache["cv"],
                                              page_table, mask_rows, cfg)
            else:
                k = paged_gather(cache["ck"], page_table, L)
                v = paged_gather(cache["cv"], page_table, L)
        else:
            k, v = cache["ck"], cache["cv"]
            aux["kv_reads"] = aux["kv_reads"] + _visible_kv_elems(
                enc_mask if enc_mask is not None
                else jnp.zeros((B, k.shape[1]), jnp.float32), KV, hd)
        new_cache = None
    else:
        k, a = emt_dense(params["wk"], enc_out, cfg.emt_at(f"{tag}/wk"),
                         tag=f"{tag}/wk", seed=ctx.seed, key=ctx.key)
        aux = add_aux(aux, a)
        v, a = emt_dense(params["wv"], enc_out, cfg.emt_at(f"{tag}/wv"),
                         tag=f"{tag}/wv", seed=ctx.seed, key=ctx.key)
        aux = add_aux(aux, a)
        k = k.reshape(*enc_out.shape[:-1], KV, hd)
        v = v.reshape(*enc_out.shape[:-1], KV, hd)
        new_cache = {"ck": k, "cv": v}
    y = fused_y if fused_y is not None else _gqa_core(q, k, v, enc_mask,
                                                      cfg, ctx)
    o, a = emt_dense(params["wo"], y, cfg.emt_at(f"{tag}/wo"), tag=f"{tag}/wo",
                     seed=ctx.seed, key=ctx.key)
    aux = add_aux(aux, a)
    return o, aux, new_cache
