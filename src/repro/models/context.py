"""Per-call context threaded through model applies (noise seeds, sharding hooks)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


def _no_shard(x, names):
    return x


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Threading context for EMT noise + activation sharding.

    seed:  uint32 scalar (traced ok) — fresh per training step so technique A sees
           new fluctuation data each batch.
    key:   PRNG key for the threefry noise backend (None with hash backend).
    shard: activation-sharding hook `f(x, logical_names) -> x`, installed by the
           distributed runner (identity on a single host).
    """
    seed: Any = 0
    key: Optional[Any] = None
    shard: Callable = _no_shard

    def with_seed(self, seed, key=None):
        return dataclasses.replace(self, seed=seed, key=key)
