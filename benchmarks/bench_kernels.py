"""Microbenchmark: paged-attention decode + chunked prefill — legacy gather
paths vs the fused one-launch kernels.

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]

Decode cases — one step of GQA attention (B rows, one query token each)
against a max_len-position KV budget, across ``block_size``, ``occupancy in
{25%, 50%, 100%}`` and ``max_len in {256, 1024}``.  Every variant now times
the step's **cache write too** (the fused kernel folds it into the attention
launch, so the legacy paths must pay their scatter for an honest ratio):

* ``contiguous``     — in-place row update + dense attention over the
  (B, max_len) contiguous cache (the pre-paging engine's decode step).
* ``gather_full``    — PR 2's fallback: pool scatter write, then
  ``paged_gather`` materializes the full (B, max_len) logical view through
  the block table, then dense attention.
* ``gather_clamped`` — the same write + gather clamped to the block-rounded
  power-of-two bucket of the furthest live position
  (``serve.engine.view_bucket``) — the current kernel-off fallback.
* ``fused``          — one launch: ``kernels.ops.paged_attention_decode``
  (in-kernel cache write via input/output aliasing + table-walk attend).
  On CPU this times the jnp reference rung (scatter + clamped-view
  batch-GEMM attend — the production CPU shape); on TPU the pallas rung
  scatters and reads block tiles inside the kernel and the view is never
  materialized, which is what the bytes model below describes.

Prefill cases — one chunked-prefill step (B rows × C query lanes) over a
**phase-mixed** batch (row lengths staggered, as the scheduler batches
mixed-phase requests), after the chunk's K/V is written (the write is
path-identical, so it is excluded from both variants):

* ``legacy_gather`` — ``attention._chunk_attend``'s old shape: materialize
  the clamped (B, view_len) logical view, dense masked attend.
* ``kernel``        — ``kernels.ops.paged_prefill``: flash-style chunk walk
  through the table with in-register causality; whole KV chunks beyond a
  row's last query position are skipped (DMA never issued).

Timing is **interleaved round-robin**: one call of each variant per
iteration, medians per variant — back-to-back per-variant loops drift with
clock/cache state and were worth >10% on the decode ratio.

Bytes-moved estimates (the quantity the paper's energy argument cares
about — crossbar/HBM K/V traffic):

* decode ``contiguous`` / ``gather_full``: B * max_len * KV * hd * 2 arrays
  * itemsize (every logical position touched, allocated or not);
  ``gather_clamped`` / ``fused``: the same over view_len (the pallas rung
  DMAs one tile per clamped-width table entry; zero-block tails still paid).
* prefill ``legacy_gather``: 2 traversals of the clamped view — the gather
  *materializes* it (pool read + view write) and the attend reads it back;
  ``kernel``: a single traversal of only the chunks a row actually needs
  (``ceil((qlast+1)/span)*span`` positions, span = block_chunk *
  block_size from ``ops.pick_block_chunk``) — strictly fewer at every
  benched occupancy, enforced below and in scripts/check_bench_json.py.

Writes a JSON report to --out (BENCH_kernels.json at the repo root) with a
``ratios`` section gated by scripts/check_bench_json.py.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.attention import paged_gather
from repro.models.common import NEG_INF
from repro.serve.engine import view_bucket


def _roundrobin_wall(variants, iters=20, warmup=2):
    """Median wall per variant, interleaved one-call-per-variant rounds."""
    for fn, args in variants.values():
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    ts = {name: [] for name in variants}
    for _ in range(iters):
        for name, (fn, args) in variants.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts[name].append(time.perf_counter() - t0)
    return {name: float(np.median(v)) * 1e6 for name, v in ts.items()}


def _attend_dense(q, k, v, mask, scale):
    """One-shot-softmax decode attention over a materialized (B, L) view."""
    s = jnp.einsum("bkgh,bskh->bkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + mask[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _attend_chunk_dense(q, k, v, mask_rows, scale):
    """Legacy chunked-prefill attend over a materialized (B, L) view.

    q (B, C, H, hd); k/v (B, L, KV, hd); mask_rows (B, C, L) additive fp32.
    The einsum form mirrors `_gqa_core`'s contraction on the gathered view.
    """
    B, C, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qt = q.reshape(B, C, KV, G, hd).transpose(0, 2, 1, 3, 4)
    qt = qt.reshape(B, KV, C * G, hd)
    s = jnp.einsum("bkrh,blkh->bkrl", qt, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + jnp.repeat(mask_rows, G, axis=1)[:, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrl,blkh->bkrh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, KV, C, G, hd).transpose(0, 2, 1, 3, 4)
    return o.reshape(B, C, H * hd)


def bench_decode_case(*, B, KV, G, hd, max_len, block_size, occupancy, dtype,
                      iters, seed=0):
    rng = np.random.default_rng(seed)
    itemsize = jnp.dtype(dtype).itemsize
    filled = max(1, int(round(occupancy * max_len)))
    width = -(-max_len // block_size)
    used = -(-filled // block_size)
    num_blocks = B * width
    scale = 1.0 / np.sqrt(hd)

    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    vp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    k_new = jnp.asarray(rng.normal(size=(B, KV, hd)), dtype)
    v_new = jnp.asarray(rng.normal(size=(B, KV, hd)), dtype)
    # per-row tables: `used` allocated blocks, rest -> zero block
    tab = np.full((B, width), num_blocks, np.int32)
    perm = rng.permutation(num_blocks)
    for b in range(B):
        tab[b, :used] = perm[b * used:(b + 1) * used]
    table = jnp.asarray(tab)
    k_cont = jnp.asarray(rng.normal(size=(B, max_len, KV, hd)), dtype)
    v_cont = jnp.asarray(rng.normal(size=(B, max_len, KV, hd)), dtype)
    idx = filled - 1                       # this step's write position
    causal = lambda L: jnp.where(  # noqa: E731
        jnp.arange(L)[None, :] <= idx, 0.0, NEG_INF).astype(
        jnp.float32) * jnp.ones((B, 1), jnp.float32)
    vlen = view_bucket(filled, block_size, max_len)
    cwidth = -(-vlen // block_size)
    wblk = jnp.asarray(tab[:, idx // block_size])       # (B,) allocated
    woff = idx % block_size
    wpos = jnp.full((B,), idx, jnp.int32)

    contiguous = jax.jit(lambda q, k, v, kn, vn: _attend_dense(
        q, k.at[:, idx].set(kn), v.at[:, idx].set(vn), causal(max_len),
        scale))

    def _scatter_gather(q, kp, vp, t, kn, vn, L):
        kp = kp.at[wblk, woff].set(kn)
        vp = vp.at[wblk, woff].set(vn)
        return _attend_dense(q, paged_gather(kp, t, L),
                             paged_gather(vp, t, L), causal(L), scale)

    gather_full = jax.jit(
        lambda q, kp, vp, t, kn, vn: _scatter_gather(
            q, kp, vp, t, kn, vn, max_len))
    gather_clamped = jax.jit(
        lambda q, kp, vp, t, kn, vn: _scatter_gather(
            q, kp, vp, t, kn, vn, vlen))
    fused = jax.jit(lambda q, kp, vp, t, kn, vn: ops.paged_attention_decode(
        q, kp, vp, t, causal(vlen), kn, vn, wpos, None, impl="auto"))

    wall = _roundrobin_wall({
        "contiguous": (contiguous, (q, k_cont, v_cont, k_new, v_new)),
        "gather_full": (gather_full, (q, kp, vp, table, k_new, v_new)),
        "gather_clamped": (gather_clamped,
                           (q, kp, vp, table[:, :cwidth], k_new, v_new)),
        "fused": (fused, (q, kp, vp, table[:, :cwidth], k_new, v_new)),
    }, iters=iters)

    kv_elem = KV * hd * 2 * itemsize
    out = {
        "kind": "decode",
        "B": B, "KV": KV, "G": G, "hd": hd, "max_len": max_len,
        "block_size": block_size, "occupancy": occupancy, "filled": filled,
        "view_len": vlen,
        "wall_us": {k: round(v, 1) for k, v in wall.items()},
        "kv_bytes_moved": {
            "contiguous": B * max_len * kv_elem,
            "gather_full": B * max_len * kv_elem,
            "gather_clamped": B * vlen * kv_elem,
            # one tile per clamped-width table entry, zero-block tail incl.
            "fused": B * cwidth * block_size * kv_elem,
        },
    }
    return out


def bench_prefill_case(*, B, KV, G, hd, max_len, block_size, occupancy,
                       chunk, dtype, iters, seed=0):
    rng = np.random.default_rng(seed)
    itemsize = jnp.dtype(dtype).itemsize
    H = KV * G
    filled = max(chunk, int(round(occupancy * max_len)))
    # phase-mixed batch: row b holds a staggered fraction of `filled`
    row_fill = np.maximum(chunk, (filled * (B - np.arange(B)) // B))
    width = -(-max_len // block_size)
    num_blocks = B * width
    scale = 1.0 / np.sqrt(hd)

    q = jnp.asarray(rng.normal(size=(B, chunk, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    vp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    tab = np.full((B, width), num_blocks, np.int32)
    perm = rng.permutation(num_blocks)
    for b in range(B):
        used = -(-int(row_fill[b]) // block_size)
        tab[b, :used] = perm[b * width:b * width + used]
    # chunk lanes end at each row's fill point (lm.chunk_step's convention)
    qpos = jnp.asarray(row_fill[:, None] - chunk + np.arange(chunk)[None, :],
                       jnp.int32)
    vlen = view_bucket(int(row_fill.max()), block_size, max_len)
    cwidth = -(-vlen // block_size)
    table = jnp.asarray(tab[:, :cwidth])
    mask_rows = jnp.where(
        jnp.arange(vlen)[None, None, :] <= qpos[:, :, None], 0.0,
        NEG_INF).astype(jnp.float32)

    legacy = jax.jit(lambda q, kp, vp, t: _attend_chunk_dense(
        q, paged_gather(kp, t, vlen), paged_gather(vp, t, vlen), mask_rows,
        scale))
    kernel = jax.jit(lambda q, kp, vp, t: ops.paged_prefill(
        q, kp, vp, t, qpos, impl="auto"))

    wall = _roundrobin_wall({
        "legacy_gather": (legacy, (q, kp, vp, table)),
        "kernel": (kernel, (q, kp, vp, table)),
    }, iters=iters)

    kv_elem = KV * hd * 2 * itemsize
    cpb = ops.pick_block_chunk(cwidth, block_size, head_dim=hd,
                               dtype_bytes=itemsize)
    span = cpb * block_size
    needed = np.minimum(vlen, -(-row_fill // span) * span)
    out = {
        "kind": "prefill",
        "B": B, "KV": KV, "G": G, "hd": hd, "max_len": max_len,
        "block_size": block_size, "occupancy": occupancy, "chunk": chunk,
        "row_fill": row_fill.tolist(), "view_len": vlen,
        "block_chunk": cpb,
        "wall_us": {k: round(v, 1) for k, v in wall.items()},
        "kv_bytes_moved": {
            # materialize the view (pool read + view write) + attend read
            "legacy_gather": 2 * B * vlen * kv_elem,
            # single traversal, whole-chunk skip past each row's last lane
            "kernel": int(needed.sum()) * kv_elem,
        },
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--arch", default=None,
                    help="derive --kv-heads/--group/--head-dim from this "
                         "arch's ServeSpec-built config instead of the "
                         "explicit shape flags (the serving-shape sweep)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the sweep for the CI bench-smoke job")
    args = ap.parse_args()
    if args.arch:
        from repro.serve.spec import ServeSpec
        cfg = ServeSpec(arch=args.arch, smoke=args.smoke).build_config()
        args.kv_heads = cfg.num_kv_heads
        args.group = cfg.num_heads // cfg.num_kv_heads
        args.head_dim = cfg.head_dim
        print(f"shape from {args.arch}: KV={args.kv_heads} G={args.group} "
              f"hd={args.head_dim}")
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.max_len = min(args.max_len, 128)
        args.iters = min(args.iters, 8)

    common = dict(B=args.batch, KV=args.kv_heads, G=args.group,
                  hd=args.head_dim, dtype=jnp.float32)
    occs = (0.25, 1.0) if args.smoke else (0.25, 0.5, 1.0)

    cases = []
    sweep = [(bs, occ, args.max_len)
             for bs in ((8, 16) if args.smoke else (8, 16, 32))
             for occ in occs]
    if not args.smoke:
        # long-context rung: chunk heuristic spans multiple blocks here
        sweep += [(32, occ, 1024) for occ in occs]
    for block_size, occupancy, max_len in sweep:
        iters = args.iters if max_len <= 256 else max(6, args.iters // 3)
        cases.append(bench_decode_case(
            max_len=max_len, block_size=block_size, occupancy=occupancy,
            iters=iters, **common))
        c = cases[-1]
        print(f"decode  bs={block_size:3d} occ={occupancy:4.0%} "
              f"L={max_len:5d} wall_us={c['wall_us']}")

    prefill_cases = []
    pf_len = 256 if args.smoke else 1024
    pf_occs = (1.0,) if args.smoke else (0.25, 0.5, 1.0)
    for occupancy in pf_occs:
        prefill_cases.append(bench_prefill_case(
            max_len=pf_len, block_size=16, occupancy=occupancy,
            chunk=16 if args.smoke else 32,
            iters=max(6, args.iters // 3), **common))
        c = prefill_cases[-1]
        print(f"prefill bs= 16 occ={occupancy:4.0%} L={pf_len:5d} "
              f"wall_us={c['wall_us']} bytes={c['kv_bytes_moved']}")

    # acceptance invariants (structural — deterministic, not wall noise):
    # at partial occupancy the fused decode path moves strictly fewer K/V
    # bytes than the materialized full gather ...
    for c in cases:
        if c["occupancy"] < 1.0:
            assert (c["kv_bytes_moved"]["fused"]
                    < c["kv_bytes_moved"]["gather_full"]), c
    # ... and the prefill kernel strictly fewer than the materialized view
    # at EVERY benched occupancy (single traversal + whole-chunk skip)
    for c in prefill_cases:
        assert (c["kv_bytes_moved"]["kernel"]
                < c["kv_bytes_moved"]["legacy_gather"]), c

    # the wall-ratio the regression gate watches: fused one-launch decode vs
    # the clamped gather fallback at full occupancy (worst case for the
    # fused path — no clamping win left, ratio is pure kernel-vs-gather)
    occ100 = [c for c in cases if c["occupancy"] == 1.0]
    ratios = [round(c["wall_us"]["fused"] / c["wall_us"]["gather_clamped"], 3)
              for c in occ100]
    report = {
        "shape": {"B": args.batch, "KV": args.kv_heads, "G": args.group,
                  "hd": args.head_dim, "max_len": args.max_len,
                  "dtype": "float32", "smoke": bool(args.smoke),
                  "arch": args.arch},
        "note": ("decode variants all include the step's cache write; "
                 "fused/kernel impls timed on the jnp reference rung (CPU "
                 "production shape); the pallas rungs write + read block "
                 "tiles in-kernel on TPU. Interleaved round-robin timing. "
                 "Bytes are the analytic K/V traffic model from the module "
                 "docstring."),
        "cases": cases,
        "prefill_cases": prefill_cases,
        "ratios": {
            "fused_vs_gather_clamped": {
                "occ100_per_case": ratios,
                "occ100_max": max(ratios),
            },
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}  fused/gather_clamped occ100 max = "
          f"{max(ratios)}")


if __name__ == "__main__":
    main()
