"""Microbenchmark: paged-attention decode — materialized gather vs fused kernel
vs contiguous-cache attention.

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]

One decode step of GQA attention (B rows, one query token each) against a
max_len-position KV budget, across ``block_size in {8, 16, 32}`` and
``occupancy in {25%, 100%}`` (fraction of max_len each row actually holds).
Four variants:

* ``contiguous``     — dense attention over the (B, max_len) contiguous cache
  (the pre-paging engine's decode read).
* ``gather_full``    — PR 2's fallback: ``paged_gather`` materializes the full
  (B, max_len) logical view through the block table, then dense attention.
* ``gather_clamped`` — the same gather clamped to the block-rounded power-of-
  two bucket of the furthest live position (``serve.engine.view_bucket``).
* ``fused``          — the fused kernel path (``kernels.ops.paged_attention``).
  On CPU this times the jnp reference rung (one-shot attend over the
  table-gathered clamped view — the production CPU shape); on TPU the pallas
  rung reads block tiles through the table inside the kernel and the view is
  never materialized, which is what the bytes model below describes.

Reported per variant: median wall time per call (jitted, device-synced) and a
**bytes-moved estimate** for K/V traffic — the quantity the paper's energy
argument cares about (crossbar/HBM reads):

* contiguous / gather_full:  B * max_len * KV * hd * 2 arrays * itemsize
  (the gather touches every logical position, allocated or not — the zero
  block is re-read for every unallocated table entry);
* gather_clamped / fused:    B * view_len * KV * hd * 2 * itemsize — the
  kernel DMAs one tile per table entry in the *clamped* width, so a pow2
  view bucket larger than the allocated blocks still pays for its zero-block
  tail (skipping zero-block chunks in-kernel is a noted follow-up); at 25%
  occupancy both move strictly fewer bytes than the max_len gather.

Writes a JSON report to --out (BENCH_kernels.json at the repo root).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.attention import paged_gather
from repro.models.common import NEG_INF
from repro.serve.engine import view_bucket


def _median_wall(fn, *args, iters=20, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _attend_dense(q, k, v, mask, scale):
    """One-shot-softmax decode attention over a materialized (B, L) view."""
    s = jnp.einsum("bkgh,bskh->bkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + mask[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def bench_case(*, B, KV, G, hd, max_len, block_size, occupancy, dtype,
               seed=0):
    rng = np.random.default_rng(seed)
    itemsize = jnp.dtype(dtype).itemsize
    filled = max(1, int(round(occupancy * max_len)))
    width = -(-max_len // block_size)
    used = -(-filled // block_size)
    num_blocks = B * width
    scale = 1.0 / np.sqrt(hd)

    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    vp = jnp.asarray(rng.normal(size=(num_blocks + 1, block_size, KV, hd)),
                     dtype).at[num_blocks].set(0.0)
    # per-row tables: `used` allocated blocks, rest -> zero block
    tab = np.full((B, width), num_blocks, np.int32)
    perm = rng.permutation(num_blocks)
    for b in range(B):
        tab[b, :used] = perm[b * used:(b + 1) * used]
    table = jnp.asarray(tab)
    k_cont = jnp.asarray(rng.normal(size=(B, max_len, KV, hd)), dtype)
    v_cont = jnp.asarray(rng.normal(size=(B, max_len, KV, hd)), dtype)
    idx = filled - 1
    causal = lambda L: jnp.where(  # noqa: E731
        jnp.arange(L)[None, :] <= idx, 0.0, NEG_INF).astype(
        jnp.float32) * jnp.ones((B, 1), jnp.float32)
    vlen = view_bucket(filled, block_size, max_len)

    contiguous = jax.jit(lambda q, k, v: _attend_dense(
        q, k, v, causal(max_len), scale))
    gather_full = jax.jit(lambda q, kp, vp, t: _attend_dense(
        q, paged_gather(kp, t, max_len), paged_gather(vp, t, max_len),
        causal(max_len), scale))
    gather_clamped = jax.jit(lambda q, kp, vp, t: _attend_dense(
        q, paged_gather(kp, t, vlen), paged_gather(vp, t, vlen),
        causal(vlen), scale))
    cwidth = -(-vlen // block_size)
    fused = jax.jit(lambda q, kp, vp, t: ops.paged_attention(
        q, kp, vp, t, causal(vlen), impl="auto"))

    kv_elem = KV * hd * 2 * itemsize
    out = {
        "B": B, "KV": KV, "G": G, "hd": hd, "max_len": max_len,
        "block_size": block_size, "occupancy": occupancy, "filled": filled,
        "view_len": vlen,
        "wall_us": {
            "contiguous": _median_wall(contiguous, q, k_cont, v_cont) * 1e6,
            "gather_full": _median_wall(gather_full, q, kp, vp, table) * 1e6,
            "gather_clamped": _median_wall(gather_clamped, q, kp, vp,
                                           table[:, :cwidth]) * 1e6,
            "fused": _median_wall(fused, q, kp, vp, table[:, :cwidth]) * 1e6,
        },
        "kv_bytes_moved": {
            "contiguous": B * max_len * kv_elem,
            "gather_full": B * max_len * kv_elem,
            "gather_clamped": B * vlen * kv_elem,
            # one tile per clamped-width table entry, zero-block tail included
            "fused": B * cwidth * block_size * kv_elem,
        },
    }
    out["wall_us"] = {k: round(v, 1) for k, v in out["wall_us"].items()}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--group", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the sweep for the CI bench-smoke job")
    args = ap.parse_args()
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.max_len = min(args.max_len, 128)

    cases = []
    for block_size in ((8, 16) if args.smoke else (8, 16, 32)):
        for occupancy in (0.25, 1.0):
            cases.append(bench_case(
                B=args.batch, KV=args.kv_heads, G=args.group,
                hd=args.head_dim, max_len=args.max_len,
                block_size=block_size, occupancy=occupancy,
                dtype=jnp.float32))
            c = cases[-1]
            print(f"bs={block_size:3d} occ={occupancy:4.0%} "
                  f"wall_us={c['wall_us']} bytes={c['kv_bytes_moved']}")

    # the acceptance invariant: at partial occupancy the fused path moves
    # strictly fewer K/V bytes than the materialized full gather
    for c in cases:
        if c["occupancy"] < 1.0:
            assert (c["kv_bytes_moved"]["fused"]
                    < c["kv_bytes_moved"]["gather_full"]), c

    report = {
        "shape": {"B": args.batch, "KV": args.kv_heads, "G": args.group,
                  "hd": args.head_dim, "max_len": args.max_len,
                  "dtype": "float32"},
        "note": ("fused impl timed on the jnp reference rung (CPU "
                 "production shape: clamped-view one-shot attend); the "
                 "pallas rung reads block tiles in-kernel on TPU. Bytes are "
                 "the analytic K/V traffic model from the module "
                 "docstring."),
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
