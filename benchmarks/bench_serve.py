"""Serving benchmark: decode tok/s + uJ/token, lockstep-equivalent vs staggered,
plus a paged-vs-contiguous KV memory/throughput comparison.

    PYTHONPATH=src python benchmarks/bench_serve.py [--out BENCH_serve.json]

Three workloads on a smoke config:

* **lockstep** — all requests arrive together with equal prompt lengths (the
  regime the old fixed-batch engine handled): every slot decodes at the same
  position.
* **staggered** — requests arrive one every `--stagger` steps with mixed
  prompt lengths: slots decode at different positions and retired slots are
  backfilled mid-decode, which the old engine could not do at all.
* **paged_vs_contiguous** — a long-context engine (`--paged-max-len`) serving
  short requests: the contiguous engine strands `max_len - need` positions
  per slot, the paged engine only holds each request's blocks, so at *less*
  KV memory it admits >= 2x the concurrent requests (reported as
  `admissible_concurrent` / `kv_bytes`, plus measured peak occupancy and
  throughput on the same workload).
* **fused_paged** — equal-batch contiguous vs paged with the fused
  paged-attention kernel + length-clamped logical views (PR 4): paged decode
  tok/s should now be >= contiguous at equal batch, on top of PR 2's
  admissible-concurrency win.
* **mixed_placement** — a heterogeneous device placement on the MoE smoke
  arch (analog attention on PCM + bit-serial MLP/experts on RRAM + digital
  SRAM router, docs/device_models.md): records tok/s and the per-corner
  uJ/token split. The corner split books *all* engine energy (including the
  idle-slot share), so it sums to `engine_total_uj` = `total_uj` (per-request
  billed) + `idle_uj`, not to `total_uj` alone.
* **shared_prefix** — N requests sharing an L-token header (50% of each
  prompt), served with refcounted prefix caching off vs on (PR 5): cache hits
  skip the shared blocks' prefill entirely, so prefill tokens computed and
  uJ/token must drop roughly with the share ratio (`prefill_tokens_ratio`
  >= 1.5 at a 50% share), while paged decode stays token-identical to the
  contiguous engine on the same workload.

`--smoke` shrinks every scenario (CI bench-smoke job: exceptions fail the
job, numbers do not).  Writes a JSON report (tok/s, uJ/token, per-request
energy spread) to --out.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest, prefill_bucket
from repro.serve.spec import ServeSpec


def _requests(rng, vocab, n, max_new, mixed):
    lens = rng.integers(4, 13, size=n) if mixed else np.full(n, 8)
    return [GenRequest(prompt=rng.integers(0, vocab, size=int(L))
                       .astype(np.int32), max_new=max_new, seed=i)
            for i, L in enumerate(lens)]


def kv_bytes(eng):
    """Total bytes held by the engine's KV cache arrays (pools incl. the zero
    block for paged; all slot regions for contiguous)."""
    leaves = jax.tree.leaves(eng.cache)
    return int(sum(x.size * x.dtype.itemsize for x in leaves))


def run_workload(cfg, params, reqs, *, stagger, batch=None, max_len=None,
                 eng=None):
    if eng is None:
        eng = ServingEngine(cfg, params, batch_size=batch, max_len=max_len)
    # warm THIS engine's jit caches (the wrappers are per-engine closures):
    # compile the decode step + every prefill bucket the workload will hit,
    # then reset the counters so the timed run starts clean.  Paged engines
    # are jit-static in the clamped view length, so the warmup must sweep
    # every view bucket the timed run can touch: each prompt bucket solo at
    # the workload's full decode budget (positions grow through every
    # intermediate bucket), then all buckets together — a cold view bucket
    # mid-run would bill a full decode-step compile to the timing.
    buckets = sorted({prefill_bucket(len(r.prompt)) for r in reqs})
    deepest = max(r.max_new for r in reqs)
    for L in buckets:
        eng.submit(GenRequest(prompt=np.zeros(L, np.int32), max_new=deepest))
        eng.drain()
    for L in buckets:
        eng.submit(GenRequest(prompt=np.zeros(L, np.int32), max_new=deepest))
    eng.drain()
    eng.reset_metrics()
    t0 = time.time()
    results = eng.serve(reqs, stagger=stagger)
    wall_s = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    uj = [r.energy_pj * 1e-6 for r in results]
    uj_tok = [e / len(r.tokens) for e, r in zip(uj, results)]
    return {
        "requests": len(results),
        "tokens": toks,
        "decode_steps": eng._steps,
        "peak_concurrent": eng.peak_concurrent,
        "wall_s": round(wall_s, 3),
        "tok_per_s": round(toks / wall_s, 2),
        "total_uj": round(sum(uj), 4),
        "idle_uj": round(eng.idle_energy_pj * 1e-6, 4),
        "uj_per_token_mean": round(float(np.mean(uj_tok)), 5),
        "uj_per_token_min": round(float(np.min(uj_tok)), 5),
        "uj_per_token_max": round(float(np.max(uj_tok)), 5),
    }


def run_paged_compare(cfg, params, *, max_len=128, block_size=8, n_requests=16,
                      max_new=8):
    """Long-context engine, short requests: equal-or-less KV memory, >= 2x
    admissible concurrency for the paged block-table cache."""
    lens = np.random.default_rng(1).integers(4, 10, size=n_requests)

    def mk_reqs():
        rng = np.random.default_rng(2)
        return [GenRequest(prompt=rng.integers(0, cfg.vocab_size, size=int(L))
                           .astype(np.int32), max_new=max_new, seed=i)
                for i, L in enumerate(lens)]

    cont = ServingEngine(cfg, params, batch_size=4, max_len=max_len)
    # pools sized for 9 concurrent worst-case requests — still fewer bytes
    # than the contiguous engine's 4 slots x max_len regions (the sliding
    # window ring pools scale with concurrency; the global pool holds blocks
    # for what requests use, not max_len per slot)
    worst = max(prefill_bucket(int(L)) for L in lens) + max_new - 1
    gpb = -(-worst // block_size)                 # global blocks per request
    paged = ServingEngine(cfg, params, batch_size=9, max_len=max_len,
                          paged=True, block_size=block_size,
                          num_blocks=9 * gpb, num_ring_blocks=9)
    ring_per_req = (paged.kv.pool_l.blocks_for(paged.kv.ring_len)
                    if paged.kv.pool_l else 0)
    admissible = {
        "contiguous": cont.batch_size,
        "paged": min(paged.batch_size,
                     paged.kv.pool_g.num_blocks // gpb,
                     (paged.kv.pool_l.num_blocks // ring_per_req
                      if ring_per_req else paged.batch_size)),
    }
    out = {
        "max_len": max_len, "block_size": block_size,
        "n_requests": n_requests, "max_new": max_new,
        "kv_bytes": {"contiguous": kv_bytes(cont), "paged": kv_bytes(paged)},
        "admissible_concurrent": admissible,
        "admissible_ratio": round(admissible["paged"] /
                                  admissible["contiguous"], 2),
        "contiguous": run_workload(cfg, params, mk_reqs(), stagger=0,
                                   eng=cont),
        "paged": run_workload(cfg, params, mk_reqs(), stagger=0, eng=paged),
    }
    out["kv_bytes"]["ratio"] = round(out["kv_bytes"]["paged"] /
                                     out["kv_bytes"]["contiguous"], 3)
    return out


def decode_wave_tok_per_s(cfg, eng, *, batch, prompt_len=8, max_new=64):
    """One lockstep wave of `batch` equal requests; only the steady decode
    steps are timed (admission + the first mixed step are not).  Every timed
    step advances `batch` active slots by one token, so tok/s = batch * steps
    / wall."""
    rng = np.random.default_rng(7)
    for i in range(batch):
        eng.submit(GenRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=prompt_len)
            .astype(np.int32), max_new=max_new, seed=i))
    eng.step()                        # admissions + first decode, untimed
    t0 = time.time()
    steps = 0
    while eng.scheduler.busy:
        eng.step()
        steps += 1
    return batch * steps / (time.time() - t0)


def run_fused_compare(*, max_len=1024, block_size=16, batch=4, max_new=64,
                      waves=4):
    """Equal-batch contiguous vs paged *decode* throughput with the fused
    kernel + clamped views — the step that turns PR 2's capacity win into a
    throughput win.

    The contiguous engine attends a (B, max_len) cache every decode step; the
    paged engine walks only the live block-rounded view through the fused
    kernel (jnp reference rung on CPU — the table-gathered clamped view; the
    pallas rung reads block tiles in-kernel on TPU).  The win scales with
    (max_len / live-view) x the share of decode spent in global attention, so
    the scenario is the regime the paged cache exists for: a dense all-global
    attention decoder (gemma3 smoke widened to d_model 256 — at the 64-wide
    smoke width, per-layer dispatch overhead drowns the attention-width
    difference on CPU; gemma3's 5-of-6 sliding-window layers would likewise
    cap the exposure at one global layer) serving short requests under a
    long-context budget.  Decode-only timing keeps prefill/admission cost —
    identical for both engines — from compressing the ratio toward 1, and the
    engines' waves are interleaved so host-load drift hits both alike (the
    first wave of each is warmup: its position sweep compiles every prefill
    and clamped-view bucket later waves touch, and is dropped from the
    medians).
    """
    cfg = ServeSpec(arch="gemma3-1b", mode="analog", smoke=True,
                    all_global=True,
                    model_overrides={"d_model": 256, "num_heads": 8,
                                     "head_dim": 32, "d_ff": 512}
                    ).build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    cont = ServingEngine(cfg, params, batch_size=batch, max_len=max_len)
    fused = ServingEngine(cfg, params, batch_size=batch, max_len=max_len,
                          paged=True, block_size=block_size)
    vals = {"contiguous": [], "fused_paged": []}
    for _ in range(waves):
        vals["contiguous"].append(decode_wave_tok_per_s(
            cfg, cont, batch=batch, max_new=max_new))
        vals["fused_paged"].append(decode_wave_tok_per_s(
            cfg, fused, batch=batch, max_new=max_new))
    out = {
        "arch": cfg.name + "-dense-attn", "max_len": max_len,
        "block_size": block_size, "batch": batch, "max_new": max_new,
        "contiguous": {"decode_tok_per_s": round(
            float(np.median(vals["contiguous"][1:])), 2)},
        "fused_paged": {"decode_tok_per_s": round(
            float(np.median(vals["fused_paged"][1:])), 2)},
    }
    out["decode_view_len"] = fused.view_len      # last step's clamped view
    out["tok_per_s_ratio"] = round(
        out["fused_paged"]["decode_tok_per_s"] /
        out["contiguous"]["decode_tok_per_s"], 3)
    return out


def run_shared_prefix(*, n_requests=8, header_len=32, tail_len=32, max_new=8,
                      batch=4, block_size=8, chunk=16, stagger=None):
    """Prefix caching off vs on at a 50% shared-prefix workload.

    N requests share an `header_len`-token header (system prompt / few-shot
    header) followed by a unique same-length tail.  With refcounted prefix
    caching the header's blocks are prefilled once and shared by every later
    admission, so `prefill_tokens_total` and uJ/token drop with the share
    ratio; the first request pays full freight.  Requests arrive staggered so
    the header blocks are registered before the next admission (the realistic
    serving regime — simultaneous cold arrivals race the registry and simply
    miss).  Energy/prefill-token numbers are analytic, so the single cold run
    is exact; wall-clock tok/s includes the same one-off compiles for both
    engines.  Also asserts paged+cache decode stays token-identical to the
    contiguous engine on the same workload (frozen noise + per-row DAC scale,
    the repo's occupancy-independent analog setting).
    """
    # prefix caching needs an all-global attention stack (ring K/V is
    # positional and cannot be shared across requests); per-row DAC scale
    # keeps analog decode occupancy-independent for the identity check
    spec = ServeSpec(arch="gemma3-1b", mode="analog", smoke=True,
                     all_global=True, a_per_row=True, paged_attn_impl="ref",
                     batch_size=batch, seed=7, frozen_noise=True,
                     prefill_chunk=chunk)
    cfg = spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    header = rng.integers(0, cfg.vocab_size, header_len).astype(np.int32)
    prompts = [np.concatenate([header, rng.integers(0, cfg.vocab_size,
                                                    tail_len).astype(np.int32)])
               for _ in range(n_requests)]
    max_len = header_len + tail_len + max_new
    if stagger is None:
        # admit the next request only after the header's blocks registered
        stagger = -(-header_len // chunk) + 1

    def mk_reqs():
        return [GenRequest(prompt=p, max_new=max_new, seed=i)
                for i, p in enumerate(prompts)]

    def mk_engine(**kw):
        return spec.replace(**kw).build_engine(cfg, params, max_len=max_len)

    out = {"arch": cfg.name + "-dense-attn", "n_requests": n_requests,
           "header_len": header_len, "tail_len": tail_len,
           "shared_fraction": round(header_len / (header_len + tail_len), 2),
           "max_new": max_new, "block_size": block_size,
           "prefill_chunk": chunk, "stagger": stagger}
    tokens = {}
    for label, kw in (("cache_off", dict(paged=True, block_size=block_size)),
                      ("cache_on", dict(paged=True, block_size=block_size,
                                        prefix_cache=True))):
        eng = mk_engine(**kw)
        t0 = time.time()
        results = eng.serve(mk_reqs(), stagger=stagger)
        wall = time.time() - t0
        tokens[label] = {r.rid: r.tokens for r in results}
        toks = sum(len(r.tokens) for r in results)
        uj = sum(r.energy_pj for r in results) * 1e-6
        out[label] = {
            "prefill_tokens_computed": eng.prefill_tokens_total,
            "cached_prefix_tokens": eng.cached_prefix_tokens,
            "decode_steps": eng._steps,
            "tokens": toks,
            "tok_per_s": round(toks / wall, 2),
            "total_uj": round(uj, 4),
            "uj_per_token": round(uj / toks, 5),
        }
        if kw.get("prefix_cache"):
            eng.kv.check()        # refcount conservation after drain
            out[label]["pool"] = {
                "hits": eng.kv.pool_g.hits,
                "evictions": eng.kv.pool_g.evictions,
                "cached_blocks_resident": eng.kv.pool_g.num_cached,
            }
    cont = mk_engine()
    cont_tokens = {r.rid: r.tokens for r in cont.serve(mk_reqs(),
                                                       stagger=stagger)}
    out["token_identity_paged_vs_contiguous"] = all(
        np.array_equal(cont_tokens[i], tokens["cache_on"][i])
        for i in cont_tokens) and all(
        np.array_equal(cont_tokens[i], tokens["cache_off"][i])
        for i in cont_tokens)
    out["prefill_tokens_ratio"] = round(
        out["cache_off"]["prefill_tokens_computed"]
        / max(out["cache_on"]["prefill_tokens_computed"], 1), 2)
    out["uj_per_token_ratio"] = round(
        out["cache_off"]["uj_per_token"]
        / max(out["cache_on"]["uj_per_token"], 1e-12), 3)
    return out


def run_mixed_placement(*, arch="moonshot-v1-16b-a3b", n_requests=8,
                        max_new=8, batch=4):
    """Heterogeneous placement serving: per-corner energy split + tok/s."""
    spec = ServeSpec(arch=arch, placement="mixed", smoke=True,
                     batch_size=batch, max_len=16 + max_new)
    cfg = spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = spec.build_engine(cfg, params)
    rng = np.random.default_rng(3)
    reqs = _requests(rng, cfg.vocab_size, n_requests, max_new, mixed=True)
    out = {"arch": cfg.name, "placement": "mixed",
           "corners": sorted(set(c for _, c, _ in cfg.placement_plan()))}
    out.update(run_workload(cfg, params, reqs, stagger=2, eng=eng))
    toks = out["tokens"]
    # corner accounting covers every crossbar read the engine issued, idle
    # rows included: sum(uj_by_corner) == engine_total_uj, not total_uj
    out["engine_total_uj"] = round(eng.total_energy_pj * 1e-6, 4)
    out["uj_per_token_by_corner"] = {
        name: round(pj * 1e-6 / toks, 5)
        for name, pj in sorted(eng.corner_energy_pj.items())}
    out["uj_by_corner"] = {name: round(pj * 1e-6, 4)
                           for name, pj in sorted(eng.corner_energy_pj.items())}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="analog")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--stagger", type=int, default=2)
    ap.add_argument("--paged-max-len", type=int, default=128,
                    help="context budget for the paged-vs-contiguous compare")
    ap.add_argument("--fused-max-len", type=int, default=1024,
                    help="context budget for the fused_paged equal-batch "
                         "compare (long-context regime)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink every scenario for the CI bench-smoke job "
                         "(fail on exceptions, not on numbers)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 4)
        args.max_new = min(args.max_new, 4)
        args.fused_max_len = min(args.fused_max_len, 256)

    cfg = ServeSpec(arch=args.arch, mode=args.mode, smoke=True).build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    max_len = 16 + args.max_new
    rng = np.random.default_rng(0)

    report = {"arch": cfg.name, "mode": args.mode, "batch": args.batch,
              "n_requests": args.requests, "max_new": args.max_new}
    report["lockstep"] = run_workload(
        cfg, params, _requests(rng, cfg.vocab_size, args.requests,
                               args.max_new, mixed=False),
        batch=args.batch, max_len=max_len, stagger=0)
    report["staggered"] = run_workload(
        cfg, params, _requests(rng, cfg.vocab_size, args.requests,
                               args.max_new, mixed=True),
        batch=args.batch, max_len=max_len, stagger=args.stagger)
    report["paged_vs_contiguous"] = run_paged_compare(
        cfg, params, max_len=args.paged_max_len,
        max_new=min(args.max_new, 8))
    report["fused_paged"] = run_fused_compare(
        max_len=args.fused_max_len,
        max_new=16 if args.smoke else 64)
    report["mixed_placement"] = run_mixed_placement(
        n_requests=args.requests, max_new=args.max_new, batch=args.batch)
    report["shared_prefix"] = run_shared_prefix(
        n_requests=4 if args.smoke else 8,
        header_len=16 if args.smoke else 32,
        tail_len=16 if args.smoke else 32,
        max_new=args.max_new, batch=args.batch)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
