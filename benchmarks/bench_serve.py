"""Serving benchmark: decode tok/s + uJ/token, lockstep-equivalent vs staggered.

    PYTHONPATH=src python benchmarks/bench_serve.py [--out BENCH_serve.json]

Two workloads on a smoke config:

* **lockstep** — all requests arrive together with equal prompt lengths (the
  regime the old fixed-batch engine handled): every slot decodes at the same
  position.
* **staggered** — requests arrive one every `--stagger` steps with mixed
  prompt lengths: slots decode at different positions and retired slots are
  backfilled mid-decode, which the old engine could not do at all.

Writes a JSON report (tok/s, uJ/token, per-request energy spread) to --out.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest, prefill_bucket


def _requests(rng, vocab, n, max_new, mixed):
    lens = rng.integers(4, 13, size=n) if mixed else np.full(n, 8)
    return [GenRequest(prompt=rng.integers(0, vocab, size=int(L))
                       .astype(np.int32), max_new=max_new, seed=i)
            for i, L in enumerate(lens)]


def run_workload(cfg, params, reqs, *, batch, max_len, stagger):
    eng = ServingEngine(cfg, params, batch_size=batch, max_len=max_len)
    # warm THIS engine's jit caches (the wrappers are per-engine closures):
    # compile the decode step + every prefill bucket the workload will hit,
    # then reset the counters so the timed run starts clean
    for L in sorted({prefill_bucket(len(r.prompt)) for r in reqs}):
        eng.submit(GenRequest(prompt=np.zeros(L, np.int32), max_new=2))
    eng.drain()
    eng._steps = 0
    eng.total_energy_pj = 0.0
    eng.idle_energy_pj = 0.0
    t0 = time.time()
    results = eng.serve(reqs, stagger=stagger)
    wall_s = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    uj = [r.energy_pj * 1e-6 for r in results]
    uj_tok = [e / len(r.tokens) for e, r in zip(uj, results)]
    return {
        "requests": len(results),
        "tokens": toks,
        "decode_steps": eng._steps,
        "wall_s": round(wall_s, 3),
        "tok_per_s": round(toks / wall_s, 2),
        "total_uj": round(sum(uj), 4),
        "idle_uj": round(eng.idle_energy_pj * 1e-6, 4),
        "uj_per_token_mean": round(float(np.mean(uj_tok)), 5),
        "uj_per_token_min": round(float(np.min(uj_tok)), 5),
        "uj_per_token_max": round(float(np.max(uj_tok)), 5),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="analog")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--stagger", type=int, default=2)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, emt_mode=args.mode, smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    max_len = 16 + args.max_new
    rng = np.random.default_rng(0)

    report = {"arch": cfg.name, "mode": args.mode, "batch": args.batch,
              "n_requests": args.requests, "max_new": args.max_new}
    report["lockstep"] = run_workload(
        cfg, params, _requests(rng, cfg.vocab_size, args.requests,
                               args.max_new, mixed=False),
        batch=args.batch, max_len=max_len, stagger=0)
    report["staggered"] = run_workload(
        cfg, params, _requests(rng, cfg.vocab_size, args.requests,
                               args.max_new, mixed=True),
        batch=args.batch, max_len=max_len, stagger=args.stagger)

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
