"""Heterogeneous speculative decoding: analog uJ/token vs plain greedy decode.

    PYTHONPATH=src python benchmarks/bench_speculative.py [--out BENCH_serve.json]

Runs the same greedy request batch twice on a calibrated analog target
placement (PCM corner, per-row DAC quantization, frozen noise):

* **baseline** — the plain continuous-batching engine, one analog decode
  step per token;
* **speculative** — `repro.serve.speculative.SpeculativeEngine`: a
  `sram_digital` draft placement (same weights, deterministic digital
  execution) proposes `--spec-k` tokens per slot, the analog target verifies
  them in one (k+1)-lane all-lane chunk step.

Because every committed token is the target's greedy token given its prefix,
the two runs are token-identical (asserted, recorded as
``token_identity``) — the comparison isolates *energy*, not quality.  The
analog win comes from amortizing the per-tile static macro-activation cost
(:meth:`repro.core.device.DeviceModel.static_energy`, the array-to-system
efficiency gap of measured PCM silicon — docs/device_models.md) over the
verify chunk's lanes; the rejected lanes' dynamic energy works against it,
so the result is a genuine function of the accept rate.

Writes the ``speculative`` section of ``BENCH_serve.json`` (merged into the
existing report).  CI gates (scripts/check_bench_json.py): accept rate in
(0, 1], draft + target energy summing to the total, conservation and token
identity flags, and — at accept rate >= 0.5 — a strictly positive analog
uJ/token improvement.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import GenRequest
from repro.serve.spec import ServeSpec

TARGET_CORNER = "pcm"
DRAFT_CORNER = "sram_digital"


def _spec(arch: str, num_layers: int, **kw) -> ServeSpec:
    # speculative decoding requires an all-global attention stack (rejected
    # drafts would clobber sliding-window ring K/V) and per-row DAC scales
    # (per-tensor activation quantization couples verify lanes, breaking
    # bit-identity with the 1-lane decode step)
    return ServeSpec(arch=arch, mode="analog", device=TARGET_CORNER,
                     smoke=True, all_global=True, a_per_row=True,
                     model_overrides={"num_layers": num_layers}, **kw)


def _requests(cfg, n, prompt_len, max_new):
    out = []
    for i in range(n):
        rng = np.random.default_rng(1000 + i)
        out.append(GenRequest(
            prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new=max_new))
    return out


def _run(eng, reqs):
    t0 = time.monotonic()
    results = eng.serve(reqs)
    wall = time.monotonic() - t0
    tokens = sum(len(r.tokens) for r in results)
    conserved = eng.energy_conserved(results)
    corners_ok = bool(np.isclose(sum(eng.corner_energy_pj.values()),
                                 eng.total_energy_pj, rtol=1e-6))
    return {
        "results": results,
        "tokens": tokens,
        "wall_s": wall,
        "tok_s": tokens / max(wall, 1e-9),
        "total_uj": eng.total_energy_pj * 1e-6,
        "corners_uj": {k: v * 1e-6 for k, v in eng.corner_energy_pj.items()},
        "energy_conserved": conserved and corners_ok,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="merge the section into this BENCH_serve.json")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink for the CI bench-smoke job")
    args = ap.parse_args()
    if args.smoke:
        # shrink the request count only: shortening max_new instead would
        # clamp k_eff on a larger fraction of rounds (the last k tokens of a
        # request draft short) and understate the static-energy amortization
        args.requests = min(args.requests, 4)

    base_spec = _spec(args.arch, args.layers, batch_size=args.batch,
                      max_len=args.prompt_len + args.max_new + 4, seed=7,
                      frozen_noise=True)
    cfg = base_spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, args.requests, args.prompt_len, args.max_new)

    base_eng = base_spec.build_engine(cfg, params)
    base = _run(base_eng, reqs)
    spec_eng = base_spec.replace(draft_placement=DRAFT_CORNER,
                                 spec_k=args.spec_k).build_engine(cfg, params)
    spec = _run(spec_eng, reqs)

    token_identity = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(base["results"], spec["results"]))

    base_analog_uj = base["corners_uj"].get(TARGET_CORNER, 0.0)
    spec_analog_uj = spec["corners_uj"].get(TARGET_CORNER, 0.0)
    draft_uj = spec_eng.draft_total_energy_pj * 1e-6
    target_uj = spec["total_uj"] - draft_uj
    base_per_tok = base_analog_uj / max(base["tokens"], 1)
    spec_per_tok = spec_analog_uj / max(spec["tokens"], 1)

    section = {
        "arch": args.arch,
        "layers": args.layers,
        "batch": args.batch,
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "max_new": args.max_new,
        "spec_k": args.spec_k,
        "target_corner": TARGET_CORNER,
        "draft_corner": DRAFT_CORNER,
        "accept_rate": round(spec_eng.accept_rate, 4),
        "accept_len_hist": spec_eng.accept_len_hist.tolist(),
        "token_identity": token_identity,
        "energy_conserved": bool(base["energy_conserved"]
                                 and spec["energy_conserved"]),
        # draft/verify split of the speculative run (uJ; CI checks the sum)
        "draft_energy_uj": round(draft_uj, 6),
        "target_energy_uj": round(target_uj, 6),
        "total_energy_uj": round(spec["total_uj"], 6),
        "baseline": {
            "analog_uj_per_token": round(base_per_tok, 6),
            "total_uj_per_token": round(base["total_uj"]
                                        / max(base["tokens"], 1), 6),
            "tok_s": round(base["tok_s"], 2),
            "corners_uj": {k: round(v, 6)
                           for k, v in base["corners_uj"].items()},
        },
        "speculative": {
            "analog_uj_per_token": round(spec_per_tok, 6),
            "total_uj_per_token": round(spec["total_uj"]
                                        / max(spec["tokens"], 1), 6),
            "tok_s": round(spec["tok_s"], 2),
            "corners_uj": {k: round(v, 6)
                           for k, v in spec["corners_uj"].items()},
        },
        "analog_uj_per_token_improvement": round(base_per_tok - spec_per_tok,
                                                 6),
    }

    if args.out:
        report = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                report = json.load(f)
        report["speculative"] = section
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    print(json.dumps({"speculative": section}, indent=2))


if __name__ == "__main__":
    main()
