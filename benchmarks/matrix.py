"""Declarative scenario-matrix executor + frontier reporting (ROADMAP item 4).

    PYTHONPATH=src python benchmarks/matrix.py [--smoke] [--out BENCH_serve.json]

One executor replaces N hand-written bench scenarios: a
:class:`repro.serve.MatrixSpec` (JSON round-trippable — ``--matrix FILE``
loads one; docs/benchmarks.md documents the schema) expands a base
:class:`ScenarioSpec` over declared axes into cells, and every cell runs
through the same engine stack (``ServeSpec.build_engine`` →
``ServingEngine``/``SpeculativeEngine``, Poisson cells through
``StreamingServer``), emitting one structured metrics dict:

* throughput — ``decode_tok_per_s`` (wall-clock; machine-dependent, never
  value-gated) and TTFT/ITL percentiles for open-loop cells,
* energy — ``uj_per_token`` (per-request billed, analytic/exact),
  ``engine_total_uj`` and the per-corner split, plus the conservation flag
  (per-request + idle == total, partials included),
* accuracy — ``accuracy_proxy``: the ablation harness trains one ideal CNN
  and evaluates it deployed on each device corner the cell's placement
  uses; the cell scores its *worst* corner (the deployment-accuracy floor
  of serving on that placement),
* identity — cells differing only along the matrix's ``identity_axes`` ran
  the same workload through different memory/kernel paths, so at
  temperature 0 + frozen noise + per-row DAC scale their tokens must match
  (the paged-vs-contiguous property, generalized to every axis slice).

``repro.analysis.frontier`` then reduces the cells to the Pareto frontier
per EMT surface (placement / corner / mode), written with the cells into
``BENCH_serve.json::matrix`` and rendered as a markdown artifact.  Two
legacy report sections (``shared_prefix``, ``poisson_load``) are also
emitted *from matrix cells* under ``matrix.legacy`` in the structure their
pre-matrix gates accept — one way to define a benchmark, not five.

The default matrix covers {placement x shared-prefix ratio x KV variant
(contiguous / paged+fused / paged+prefix-cache)} plus an open-loop Poisson
cell; ``--smoke`` shrinks it to the 2x2 CI slice (+ the Poisson cell).
``scripts/check_bench_json.py`` gates the section through the ``matrix``
entry of its gate registry.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time
from collections import Counter

import numpy as np

from repro.analysis.frontier import frontier_markdown, frontier_report
from repro.serve.engine import GenRequest, prefill_bucket
from repro.serve.scheduler import RejectedError
from repro.serve.server import StreamingServer
from repro.serve.spec import MatrixSpec, ScenarioSpec, ServeSpec

try:  # package import (tests) vs script execution (CI, CLI)
    from benchmarks.bench_latency import _pct_ms
except ImportError:
    from bench_latency import _pct_ms


# -- the default matrix ------------------------------------------------------

KV_AXIS = {
    "contiguous": {"label": "contiguous",
                   "set": {"serve.paged": False, "serve.prefix_cache": False,
                           "serve.fused_paged_attn": False}},
    "paged_fused": {"label": "paged_fused",
                    "set": {"serve.paged": True, "serve.block_size": 8,
                            "serve.fused_paged_attn": True,
                            "serve.prefix_cache": False}},
    "paged_prefix": {"label": "paged_prefix",
                     "set": {"serve.paged": True, "serve.block_size": 8,
                             "serve.fused_paged_attn": True,
                             "serve.prefix_cache": True}},
}


def default_matrix(smoke: bool = False) -> MatrixSpec:
    """{placement x shared-prefix ratio x KV variant} + one Poisson cell.

    The base is the repo's determinism setting (frozen noise + per-row DAC
    scale + temperature 0 + all-global stack) so the KV axis is an identity
    axis: every KV variant of a slice must produce the same tokens.
    Arrivals are staggered two steps so a prefix-cache cell's header blocks
    register before the next admission (the realistic serving regime).
    """
    serve = ServeSpec(arch="gemma3-1b", mode="analog", smoke=True,
                      all_global=True, a_per_row=True, frozen_noise=True,
                      seed=7, batch_size=4, prefill_chunk=16,
                      paged_attn_impl="ref")
    base = ScenarioSpec(name="grid", serve=serve, arrival="stagger",
                        stagger=2, n_requests=4 if smoke else 8,
                        prompt_lo=32, prompt_hi=32,
                        max_new=4 if smoke else 8, workload_seed=11)
    axes = {
        "shared_prefix_ratio": (0.0, 0.5),
        "kv": ((KV_AXIS["paged_fused"], KV_AXIS["paged_prefix"]) if smoke
               else (KV_AXIS["contiguous"], KV_AXIS["paged_fused"],
                     KV_AXIS["paged_prefix"])),
    }
    if not smoke:
        axes = {"serve.placement": (None, "mixed"), **axes}
    poisson = ScenarioSpec(
        name="poisson", arrival="poisson",
        serve=serve.replace(paged=True, block_size=8, max_pending=16),
        rate_rps=20.0 if smoke else 4.0, n_requests=8 if smoke else 16,
        prompt_lo=6, prompt_hi=20, max_new=6 if smoke else 12,
        workload_seed=5)
    return MatrixSpec(name="serve-frontier-smoke" if smoke
                      else "serve-frontier", base=base, axes=axes,
                      identity_axes=("kv",), extra_cells=(poisson,))


# -- workload ----------------------------------------------------------------

def make_requests(cell: ScenarioSpec, vocab: int):
    """Deterministic request list for a cell: an optional shared header
    (``shared_prefix_ratio`` of ``prompt_lo``) + unique tails, lengths
    uniform in [prompt_lo, prompt_hi].  Depends only on the workload fields
    (never on serve/engine knobs), so cells in one identity group serve the
    exact same requests."""
    rng = np.random.default_rng(cell.workload_seed + 1_000)
    header = rng.integers(0, vocab, cell.header_len).astype(np.int32)
    kw = cell.serve.request_kwargs()
    reqs = []
    for i in range(cell.n_requests):
        n = int(rng.integers(cell.prompt_lo, cell.prompt_hi + 1))
        tail = rng.integers(0, vocab,
                            max(1, n - cell.header_len)).astype(np.int32)
        reqs.append(GenRequest(prompt=np.concatenate([header, tail]),
                               max_new=cell.max_new, seed=i, **kw))
    return reqs


def _warm(eng, reqs):
    """Compile every prefill bucket / view depth the run touches, then open
    the books fresh (the same discipline as the latency bench's warmup)."""
    buckets = sorted({prefill_bucket(len(r.prompt)) for r in reqs})
    deepest = max(r.max_new for r in reqs)
    for n in buckets:
        eng.submit(GenRequest(prompt=np.zeros(n, np.int32), max_new=deepest))
        eng.drain()
    for n in buckets:
        eng.submit(GenRequest(prompt=np.zeros(n, np.int32), max_new=deepest))
    eng.drain()
    eng.reset_metrics()


# -- accuracy proxy ----------------------------------------------------------
#
# One ideal-trained CNN (the ablation harness's `traditional` method on the
# vgg_small task), deployed per device corner via the rho graft — cached per
# corner, so a whole matrix pays one short training run plus one evaluation
# per distinct corner.  The proxy is *relative* (which placement degrades
# accuracy, and by how much), matching the paper's Fig. 9 framing; absolute
# values are synthetic-task accuracies.

_PROXY_CACHE: dict = {}


def _ablation():
    try:
        from benchmarks import ablation_lib
    except ImportError:
        import ablation_lib
    return ablation_lib


def _ideal_cnn(steps: int):
    key = ("__ideal__", steps)
    if key not in _PROXY_CACHE:
        ab = _ablation()
        from repro.configs.paper_cnn import vgg_small
        cfg = ab.method_config(vgg_small(), "traditional", 4.0)
        _PROXY_CACHE[key] = (cfg, ab.train_cnn(cfg, steps=steps))
    return _PROXY_CACHE[key]


def _corner_acc(corner: str, mode: str, *, steps: int, batches: int) -> float:
    key = (corner, mode, steps, batches)
    if key in _PROXY_CACHE:
        return _PROXY_CACHE[key]
    ab = _ablation()
    cfg, params = _ideal_cnn(steps)
    if mode in ("ideal", "fp32"):
        acc, _ = ab.evaluate(cfg, params, batches=batches)
    else:
        if corner in ("", mode):      # default (paper PCM-like) cell
            emt = ab._emt(mode, 4.0, trainable=False)
        else:
            from repro.core.placement import emt_for_corner
            emt = emt_for_corner(corner, mode)
        dep = dataclasses.replace(cfg, emt=emt)
        acc, _ = ab.evaluate(dep, ab._with_rho(dep, params), batches=batches)
    _PROXY_CACHE[key] = float(acc)
    return _PROXY_CACHE[key]


def accuracy_proxy(cfg, *, steps: int, batches: int):
    """(worst-corner accuracy, {corner: accuracy}) for a serving config."""
    pairs = sorted({(c, m) for _, c, m in cfg.placement_plan()})
    by_corner = {c or m: _corner_acc(c, m, steps=steps, batches=batches)
                 for c, m in pairs}
    return min(by_corner.values()), by_corner


# -- per-cell execution ------------------------------------------------------

def _params_key(spec: ServeSpec):
    """Cells sharing weights: everything that shapes lm.specs(cfg)."""
    return (spec.arch, spec.smoke, spec.mode, spec.device, spec.placement,
            spec.all_global, json.dumps(spec.model_overrides, sort_keys=True))


def _token_fingerprint(tokens: dict) -> str:
    h = hashlib.sha1()
    for rid in sorted(tokens):
        h.update(np.asarray(tokens[rid], np.int64).tobytes())
        h.update(b"|")
    return h.hexdigest()[:16]


def run_cell(cell: ScenarioSpec, *, params_cache: dict, proxy_steps: int,
             proxy_batches: int, with_proxy: bool = True):
    """Run one cell; returns (metrics dict, {rid: token array})."""
    import jax

    from repro.models import lm
    from repro.nn.param import init_params

    spec = cell.serve
    cfg = spec.build_config()
    key = _params_key(spec)
    if key not in params_cache:
        params_cache[key] = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    params = params_cache[key]
    max_len = spec.max_len or prefill_bucket(cell.prompt_hi) + cell.max_new
    eng = spec.build_engine(cfg, params, max_len=max_len)
    reqs = make_requests(cell, cfg.vocab_size)
    _warm(eng, reqs)

    handles, rejected = [], 0
    if cell.arrival == "poisson":
        rng = np.random.default_rng(cell.workload_seed)
        arrivals = np.cumsum(rng.exponential(1.0 / cell.rate_rps,
                                             len(reqs)))
        with StreamingServer(eng, max_pending=spec.max_pending) as srv:
            t0 = time.monotonic()
            for r, at in zip(reqs, arrivals):
                delay = t0 + at - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
                try:
                    handles.append(srv.submit(r, deadline_s=spec.deadline_s))
                except RejectedError:
                    rejected += 1
            results = [h.result(timeout=600) for h in handles]
            wall = time.monotonic() - t0
    else:
        stagger = cell.stagger if cell.arrival == "stagger" else 0
        t0 = time.monotonic()
        results = eng.serve(reqs, stagger=stagger)
        wall = time.monotonic() - t0

    tokens = {r.rid: np.asarray(r.tokens) for r in results}
    toks = sum(len(t) for t in tokens.values())
    billed_uj = sum(r.energy_pj for r in results) * 1e-6
    em = eng.metrics()
    out = {
        "name": cell.name,
        "coords": [list(c) for c in cell.coords],
        "emt_label": spec.emt_label,
        "arrival": cell.arrival,
        "n_requests": len(reqs),
        "shared_prefix_ratio": cell.shared_prefix_ratio,
        "header_len": cell.header_len,
        "kv": "paged" if spec.paged else "contiguous",
        "prefix_cache": spec.prefix_cache,
        "tokens": toks,
        "wall_s": round(wall, 3),
        "decode_tok_per_s": round(toks / wall, 2) if wall else None,
        "steps": em["steps"],
        "peak_concurrent": em["peak_concurrent"],
        "total_uj": round(billed_uj, 4),
        "idle_uj": round(em["idle_energy_pj"] * 1e-6, 4),
        "engine_total_uj": round(em["total_energy_pj"] * 1e-6, 4),
        "uj_per_token": round(billed_uj / max(toks, 1), 5),
        "uj_per_token_by_corner": {
            k: round(v * 1e-6 / max(toks, 1), 5)
            for k, v in sorted(em["corner_energy_pj"].items())},
        "prefill_tokens_computed": em["prefill_tokens_total"],
        "cached_prefix_tokens": em["cached_prefix_tokens"],
        "energy_conserved": eng.energy_conserved(results),
        "done_reasons": dict(sorted(Counter(
            r.done_reason for r in results).items())),
        "token_fingerprint": _token_fingerprint(tokens),
    }
    if cell.arrival == "poisson":
        out["rejected"] = rejected
        out["offered_rate_rps"] = cell.rate_rps
        out["throughput_tok_per_s"] = out.pop("decode_tok_per_s")
        out["decode_tok_per_s"] = out["throughput_tok_per_s"]
        out["ttft_ms"] = _pct_ms([h.ttft_s for h in handles
                                  if h.ttft_s is not None])
        out["inter_token_ms"] = _pct_ms([d for h in handles
                                         for d in h.itl_s])
    if spec.draft_placement is not None:
        out["speculation"] = {k: em[k] for k in
                              ("accept_rate", "spec_rounds",
                               "spec_proposed_total", "spec_accepted_total",
                               "accept_len_hist", "draft_total_energy_pj")}
    if with_proxy:
        out["accuracy_proxy"], out["accuracy_by_corner"] = accuracy_proxy(
            cfg, steps=proxy_steps, batches=proxy_batches)
    return out, tokens


# -- cross-cell reductions ---------------------------------------------------

def check_identity(matrix: MatrixSpec, cells, metrics, tokens):
    """Token identity across each identity-axis slice: cells whose coords
    match outside ``identity_axes`` served the same workload, so their token
    streams must agree request-for-request.  Stamps ``token_identity`` on
    every grouped cell; returns the per-group summary."""
    groups: dict = {}
    for i, c in enumerate(cells):
        if not c.coords:
            continue
        groups.setdefault(c.group_key(matrix.identity_axes), []).append(i)
    report = {}
    for gkey, idx in sorted(groups.items()):
        ref = tokens[idx[0]]
        same = all(
            set(tokens[i]) == set(ref)
            and all(np.array_equal(tokens[i][r], ref[r]) for r in ref)
            for i in idx[1:])
        for i in idx:
            metrics[i]["token_identity"] = bool(same)
        label = "/".join(f"{a}={v}" for a, v in gkey) or "all"
        report[label] = {"cells": [metrics[i]["name"] for i in idx],
                         "identical": bool(same)}
    return report


def _cell_at(cells, metrics, **coords):
    for c, m in zip(cells, metrics):
        have = dict(c.coords)
        if all(have.get(k) == v for k, v in coords.items()):
            yield c, m


def legacy_sections(matrix: MatrixSpec, cells, metrics):
    """Re-emit pre-matrix report sections from matrix cells, in the exact
    structure their existing gates accept (the proof the matrix subsumes
    the hand-written scenarios)."""
    legacy = {}
    # shared_prefix: the shared=0.5 KV slice on the default placement
    slice_ = [(c, m) for c, m in _cell_at(cells, metrics,
                                          shared_prefix_ratio="0.5")
              if dict(c.coords).get("serve.placement", "none") == "none"]
    by_kv = {dict(c.coords)["kv"]: (c, m) for c, m in slice_
             if "kv" in dict(c.coords)}
    if {"paged_fused", "paged_prefix"} <= set(by_kv):
        off_c, off = by_kv["paged_fused"]
        _, on = by_kv["paged_prefix"]

        def sub(m):
            return {k: m[k] for k in
                    ("prefill_tokens_computed", "cached_prefix_tokens",
                     "tokens", "total_uj", "uj_per_token",
                     "decode_tok_per_s")}
        legacy["shared_prefix"] = {
            "source": "matrix",
            "n_requests": off_c.n_requests,
            "header_len": off_c.header_len,
            "shared_fraction": off_c.shared_prefix_ratio,
            "stagger": off_c.stagger,
            "cache_off": sub(off),
            "cache_on": sub(on),
            # every cell in the slice (contiguous included when the full
            # matrix runs it) decoded identical tokens
            "token_identity_paged_vs_contiguous": all(
                m.get("token_identity", False) for _, m in by_kv.values()),
            "prefill_tokens_ratio": round(
                off["prefill_tokens_computed"]
                / max(on["prefill_tokens_computed"], 1), 2),
            "uj_per_token_ratio": round(
                off["uj_per_token"] / max(on["uj_per_token"], 1e-12), 3),
        }
    # poisson_load: the open-loop extra cell
    for c, m in zip(cells, metrics):
        if c.arrival != "poisson":
            continue
        legacy["poisson_load"] = {
            "source": "matrix",
            "offered_rate_rps": c.rate_rps,
            "n_requests": c.n_requests,
            "submitted": c.n_requests - m.get("rejected", 0),
            "rejected": m.get("rejected", 0),
            "done_reasons": m["done_reasons"],
            "tokens": m["tokens"],
            "wall_s": m["wall_s"],
            "throughput_tok_per_s": m.get("throughput_tok_per_s"),
            "peak_concurrent": m["peak_concurrent"],
            "ttft_ms": m.get("ttft_ms"),
            "inter_token_ms": m.get("inter_token_ms"),
            "total_uj": m["total_uj"],
            "idle_uj": m["idle_uj"],
            "energy_conserved_with_partials": m["energy_conserved"],
        }
        break
    return legacy


def run_matrix(matrix: MatrixSpec, *, only=None, proxy_steps=60,
               proxy_batches=4, with_proxy=True, verbose=True):
    """Expand + execute a matrix; returns the ``matrix`` report section."""
    cells = matrix.expand()
    if only:
        known = {c.name for c in cells}
        unknown = sorted(set(only) - known)
        if unknown:
            raise SystemExit(f"unknown cell(s) {unknown}; known: "
                             f"{sorted(known)}")
        cells = [c for c in cells if c.name in only]
    params_cache: dict = {}
    metrics, tokens = [], []
    for cell in cells:
        t0 = time.time()
        m, toks = run_cell(cell, params_cache=params_cache,
                           proxy_steps=proxy_steps,
                           proxy_batches=proxy_batches,
                           with_proxy=with_proxy)
        metrics.append(m)
        tokens.append(toks)
        if verbose:
            print(f"cell {m['name']}: {m['tokens']} tok, "
                  f"{m['decode_tok_per_s']} tok/s, "
                  f"{m['uj_per_token']} uJ/tok "
                  f"[{time.time() - t0:.1f}s]", flush=True)
    identity = check_identity(matrix, cells, metrics, tokens)
    section = {
        "spec": matrix.to_dict(),
        "cells": metrics,
        "identity": identity,
        "frontier": frontier_report(metrics),
        "legacy": legacy_sections(matrix, cells, metrics),
    }
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default=None,
                    help="JSON MatrixSpec file (default: built-in serve "
                         "frontier matrix; see docs/benchmarks.md)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="merged into this report under 'matrix'")
    ap.add_argument("--markdown", default="FRONTIER_matrix.md",
                    help="frontier table artifact ('' disables)")
    ap.add_argument("--only", default=None,
                    help="comma-separated cell names to run (unknown names "
                         "error with the known list)")
    ap.add_argument("--list", action="store_true",
                    help="print the expanded cell names and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny 2x2 slice (+ Poisson cell) for the CI "
                         "matrix-smoke job")
    ap.add_argument("--no-proxy", action="store_true",
                    help="skip the accuracy proxy (frontier degenerates to "
                         "throughput vs energy)")
    ap.add_argument("--proxy-steps", type=int, default=None,
                    help="CNN training steps behind the accuracy proxy "
                         "(default 30 smoke / 120 full)")
    args = ap.parse_args()

    if args.matrix:
        with open(args.matrix) as f:
            matrix = MatrixSpec.from_dict(json.load(f))
    else:
        matrix = default_matrix(smoke=args.smoke)
    if args.list:
        for c in matrix.expand():
            print(c.name)
        return
    only = [n for n in (args.only or "").split(",") if n] or None
    proxy_steps = args.proxy_steps or (30 if args.smoke else 120)
    section = run_matrix(matrix, only=only, proxy_steps=proxy_steps,
                         proxy_batches=2 if args.smoke else 8,
                         with_proxy=not args.no_proxy)

    report = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            report = json.load(f)
    report["matrix"] = section
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    if args.markdown:
        md = ["# Serving trade-off frontier",
              "",
              f"matrix `{matrix.name}`: {len(section['cells'])} cells; "
              f"axes: " + ", ".join(
                  f"{a['metric']} ({a['goal']})"
                  for a in section["frontier"]["axes"]),
              "",
              frontier_markdown(section["cells"], section["frontier"]), ""]
        with open(args.markdown, "w") as f:
            f.write("\n".join(md))
    print(json.dumps({"frontier": section["frontier"],
                      "identity": section["identity"]}, indent=2))


if __name__ == "__main__":
    main()
