"""Shared machinery for the paper-reproduction experiments (Figs. 7/9/10, Tables 1-2).

Methods (paper §5 notation):
    traditional — train ideal (noise-unaware), deploy on analog EMT
    A           — device-enhanced dataset (noise-aware training), fixed rho
    A+B         — + energy regularization (trainable rho, lambda sweep)
    A+B+C       — + low-fluctuation bit-serial decomposition

Dataset note: CIFAR/ImageNet are not on this box; experiments run on the
deterministic synthetic image task (repro.data.SyntheticImages) — orderings and
trends are the reproduction target, not absolute accuracies (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import vgg_small, resnet_small
from repro.configs.common import emt_preset
from repro.core.emt_linear import EMTConfig
from repro.core.quant import QuantConfig
from repro.core.noise import NoiseConfig
from repro.core.device import DeviceModel
from repro.data.synthetic import SyntheticImages
from repro.models import cnn
from repro.models.context import Ctx
from repro.nn.param import init_params
from repro.train.optimizer import Optimizer, OptimizerConfig


def _emt(mode, rho, trainable, intensity="normal"):
    return EMTConfig(
        mode=mode, quant=QuantConfig(8, 8, True),
        noise=NoiseConfig(backend="hash"),
        device=DeviceModel(intensity=intensity),
        rho_init=rho, trainable_rho=trainable)


def method_config(base_cfg, method: str, rho: float, intensity="normal"):
    if method == "traditional":
        emt = EMTConfig(mode="ideal", quant=QuantConfig(8, 8, True))
    elif method == "A":
        emt = _emt("analog", rho, trainable=False, intensity=intensity)
    elif method == "A+B":
        emt = _emt("analog", rho, trainable=True, intensity=intensity)
    elif method == "A+B+C":
        emt = _emt("bitserial", rho, trainable=True, intensity=intensity)
    else:
        raise ValueError(method)
    return dataclasses.replace(base_cfg, emt=emt)


def train_cnn(cfg, *, steps=200, batch=32, lr=5e-3, lam=0.0, seed=0):
    data = SyntheticImages(num_classes=cfg.num_classes,
                           image_size=cfg.image_size, seed=seed)
    params = init_params(cnn.specs(cfg), jax.random.PRNGKey(seed))
    opt = Optimizer(OptimizerConfig(name="adamw"))
    ost = opt.init(params)

    @jax.jit
    def step(params, ost, images, labels, s):
        ctx = Ctx(seed=s)

        def loss_fn(p):
            return cnn.loss_fn(p, {"images": images, "labels": labels},
                               cfg, ctx, lam=lam)
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, ost = opt.update(g, ost, params, lr, s.astype(jnp.int32))
        return params, ost, m

    for s in range(steps):
        b = data.batch(batch, s)
        params, ost, m = step(params, ost, jnp.asarray(b["images"]),
                              jnp.asarray(b["labels"]), jnp.uint32(s))
    return params


def evaluate(cfg, params, *, batches=8, batch=64, seed=10_000):
    """Accuracy + mean per-inference EMT energy (uJ) under fresh fluctuations."""
    data = SyntheticImages(num_classes=cfg.num_classes,
                           image_size=cfg.image_size, seed=0)
    ctx_seed = seed

    @jax.jit
    def fwd(params, images, s):
        logits, aux = cnn.forward(params, images, cfg, Ctx(seed=s))
        return logits, aux["energy_pj"]

    accs, energies = [], []
    for i in range(batches):
        b = data.batch(batch, i, split="test")
        logits, e = fwd(params, jnp.asarray(b["images"]),
                        jnp.uint32(ctx_seed + i))
        accs.append(float(jnp.mean(
            (jnp.argmax(logits, -1) == jnp.asarray(b["labels"]))
            .astype(jnp.float32))))
        energies.append(float(e) / batch)   # per-image pJ
    return float(np.mean(accs)), float(np.mean(energies)) * 1e-6  # -> uJ


def run_method(base_cfg, method, *, rho=4.0, lam=1e-7, steps=120,
               intensity="normal", eval_rho=None, seed=0):
    """Train once, evaluate deployed-on-EMT. Returns dict of results."""
    cfg = method_config(base_cfg, method, rho, intensity)
    t0 = time.time()
    params = train_cnn(cfg, steps=steps, lam=lam if "B" in method else 0.0,
                       seed=seed)
    train_s = time.time() - t0

    # deployment config: traditional deploys on analog hardware at eval_rho
    if method == "traditional":
        dep = dataclasses.replace(
            cfg, emt=_emt("analog", eval_rho or rho, trainable=False,
                          intensity=intensity))
        # graft a rho param for evaluation
        dep_params = _with_rho(dep, params)
    else:
        dep, dep_params = cfg, params
    acc, energy = evaluate(dep, dep_params)
    rho_final = _mean_rho(dep, dep_params)
    return {"method": method, "acc": acc, "energy_uj": energy,
            "rho": rho_final, "train_s": round(train_s, 1), "lam": lam}


def _with_rho(cfg, params):
    """Graft trained (ideal) weights into the deployment spec that adds rho_raw."""
    ref = init_params(cnn.specs(cfg), jax.random.PRNGKey(0))
    flat_ref, _ = jax.tree_util.tree_flatten_with_path(ref)
    flat_old = dict(_walk(params))
    leaves = []
    for path, leaf in flat_ref:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        leaves.append(flat_old.get(key, leaf))   # new rho_raw keeps its init
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(ref), leaves)


def _walk(tree, prefix=""):
    import jax as _jax
    flat, _ = _jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        yield key, leaf


def _find(params, key, default):
    for k, v in _walk(params):
        if k.endswith(key):
            return v
    return default


def _mean_rho(cfg, params):
    from repro.core.regularizer import rho_from_raw
    vals = [float(rho_from_raw(v)) for k, v in _walk(params)
            if k.endswith("rho_raw")]
    return float(np.mean(vals)) if vals else float("nan")
