"""Benchmark harness — one function per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,kernels] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (derived = the table/figure quantity
the row reproduces). Heavy benches honor --fast for CI-scale runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


# ---------------------------------------------------------------- Fig. 9
def bench_fig9_ablation(fast=False):
    """Accuracy vs energy for traditional/A/A+B/A+B+C (paper Fig. 9)."""
    from benchmarks.ablation_lib import run_method
    from repro.configs.paper_cnn import vgg_small
    cfg = vgg_small()
    steps = 80 if fast else 220
    rows = []
    for method, kw in [
        ("traditional", dict(rho=4.0, eval_rho=4.0)),
        ("A", dict(rho=4.0)),
        ("A+B", dict(rho=4.0, lam=3e-8)),
        ("A+B+C", dict(rho=4.0, lam=3e-8)),
    ]:
        t0 = time.time()
        r = run_method(cfg, method, steps=steps, **kw)
        us = (time.time() - t0) * 1e6
        _row(f"fig9/{method}", us,
             f"acc={r['acc']:.3f};energy_uJ={r['energy_uj']:.4f};"
             f"rho={r['rho']:.2f}")
        rows.append(r)
    order = {r["method"]: r for r in rows}
    _row("fig9/acc_ordering", 0,
         f"traditional<=A holds={order['traditional']['acc'] <= order['A']['acc'] + 0.02}")
    _row("fig9/energy_A+B+C<A+B", 0,
         f"holds={order['A+B+C']['energy_uj'] < order['A+B']['energy_uj']}")
    return rows


# ---------------------------------------------------------------- Fig. 10
def bench_fig10_robustness(fast=False):
    """Weak/normal/strong fluctuation intensity (paper Fig. 10)."""
    from benchmarks.ablation_lib import run_method
    from repro.configs.paper_cnn import resnet_small
    cfg = resnet_small()
    steps = 70 if fast else 180
    for intensity in ("weak", "normal", "strong"):
        t0 = time.time()
        r = run_method(cfg, "A+B", rho=4.0, lam=3e-8, steps=steps,
                       intensity=intensity)
        us = (time.time() - t0) * 1e6
        _row(f"fig10/A+B/{intensity}", us,
             f"acc={r['acc']:.3f};energy_uJ={r['energy_uj']:.4f};"
             f"rho={r['rho']:.2f}")


# ---------------------------------------------------------------- Fig. 7
def bench_fig7_energy_reg(fast=False):
    """rho and sum|w| descend under the energy regularizer (paper Fig. 7)."""
    import jax
    import jax.numpy as jnp
    from repro.core import EMTConfig, emt_dense, dense_specs
    from repro.core.regularizer import rho_from_raw
    from repro.nn.param import init_params
    from repro.train.optimizer import Optimizer, OptimizerConfig

    cfg = EMTConfig(mode="analog", rho_init=8.0)
    specs = dense_specs(64, 64, cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    y_t = x @ init_params(specs, jax.random.PRNGKey(2))["w"]
    opt = Optimizer(OptimizerConfig(name="adamw"))
    ost = opt.init(params)
    lam = 2e-4

    @jax.jit
    def step(params, ost, s):
        def loss(p):
            y, aux = emt_dense(p, x, cfg, tag="t", seed=s)
            return jnp.mean((y - y_t) ** 2) + lam * aux["reg"]
        l, g = jax.value_and_grad(loss)(params)
        params, ost = opt.update(g, ost, params, 3e-3, s.astype(jnp.int32))
        return params, ost, l

    rho0 = float(rho_from_raw(params["rho_raw"]))
    w0 = float(jnp.sum(jnp.abs(params["w"])))
    t0 = time.time()
    steps = 100 if fast else 400
    for s in range(steps):
        params, ost, l = step(params, ost, jnp.uint32(s))
    us = (time.time() - t0) * 1e6 / steps
    rho1 = float(rho_from_raw(params["rho_raw"]))
    w1 = float(jnp.sum(jnp.abs(params["w"])))
    _row("fig7/energy_reg_descent", us,
         f"rho:{rho0:.2f}->{rho1:.2f};sum_w:{w0:.1f}->{w1:.1f};"
         f"both_decreased={rho1 < rho0 and w1 < w0}")


# ---------------------------------------------------------------- Tables 1-2
def bench_tables(fast=False):
    """Energy / #cells / delay structure of paper Tables 1 & 2.

    #cells and delay come from the analytic device model on the paper's full
    CNN configs; the energy/accuracy trade-off is measured on the small
    (CPU-trainable) variants of the same families.
    """
    from benchmarks.ablation_lib import run_method
    from repro.configs.paper_cnn import (vgg16_cifar, resnet18_cifar,
                                         vgg_small, resnet_small)
    from repro.models import cnn
    from repro.nn.param import abstract_params
    from repro.utils import tree_param_count

    for name, full_cfg, small_cfg in [
            ("vgg16", vgg16_cifar(), vgg_small()),
            ("resnet18", resnet18_cifar(), resnet_small())]:
        cells = tree_param_count(abstract_params(cnn.specs(full_cfg)))
        delay_a = 2.8                                   # single analog read pass
        delay_c = delay_a * (full_cfg.emt.quant.a_bits - 1) / 1.4  # bit-serial
        steps = 70 if fast else 180
        r_ab = run_method(small_cfg, "A+B", rho=4.0, lam=3e-8, steps=steps)
        r_abc = run_method(small_cfg, "A+B+C", rho=4.0, lam=3e-8, steps=steps)
        _row(f"table1/{name}/cells", 0, f"cells={cells/1e6:.2f}M")
        _row(f"table1/{name}/A+B", r_ab["train_s"] * 1e6,
             f"energy_uJ={r_ab['energy_uj']:.4f};delay_us={delay_a};"
             f"acc={r_ab['acc']:.3f}")
        _row(f"table1/{name}/A+B+C", r_abc["train_s"] * 1e6,
             f"energy_uJ={r_abc['energy_uj']:.4f};delay_us={delay_c:.1f};"
             f"acc={r_abc['acc']:.3f}")
        _row(f"table1/{name}/energy_ratio", 0,
             f"A+B_over_A+B+C="
             f"{r_ab['energy_uj']/max(r_abc['energy_uj'],1e-9):.1f}x")


# ---------------------------------------------------------------- kernels
def bench_kernels(fast=False):
    import jax
    import jax.numpy as jnp
    from repro.core.device import DeviceModel
    from repro.kernels import ops, ref

    dev = DeviceModel()
    m = k = n = 256 if fast else 512
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    xq = jnp.round(jnp.clip(x * 20, -127, 127))

    for name, fn in [
        ("ref/emt_matmul", lambda: ref.emt_matmul_ref(x, w, 4.0, device=dev)),
        ("ref/bitserial", lambda: ref.emt_bitserial_ref(xq, w, 4.0, device=dev,
                                                        bits=7)),
        ("jnp/ideal_matmul", lambda: x @ w),
    ]:
        jfn = jax.jit(fn)
        jax.block_until_ready(jfn())  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(jfn())
        us = (time.time() - t0) / reps * 1e6
        flops = 2 * m * k * n * (7 if "bitserial" in name else 1)
        _row(f"kernel/{name}", us, f"gflops_cpu={flops/us/1e3:.2f}")


# ---------------------------------------------------------------- roofline
def bench_roofline(fast=False):
    """Summarize the dry-run roofline table (reads experiments/dryrun/*.json)."""
    import glob
    import json
    import os
    pat = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun", "*.json")
    files = sorted(glob.glob(pat))
    if not files:
        _row("roofline/none", 0, "no dryrun results yet")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            _row(f"roofline/{os.path.basename(f)}", 0, "status=error")
            continue
        r = rec["roofline"]
        _row(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             rec["compile_s"] * 1e6,
             f"dom={r['dominant']};bound_ms={r['step_time_lower_bound_s']*1e3:.1f};"
             f"frac={r['roofline_fraction']:.3f};useful={r['useful_flops_ratio']:.3f};"
             f"peak_GB={rec['peak_bytes_per_chip']/2**30:.2f}")


BENCHES = {
    "fig7": bench_fig7_energy_reg,
    "fig9": bench_fig9_ablation,
    "fig10": bench_fig10_robustness,
    "tables": bench_tables,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench name(s) {unknown}; known: "
                         f"{', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n](fast=args.fast)


if __name__ == "__main__":
    main()
