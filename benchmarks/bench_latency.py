"""Open-loop Poisson load generator: TTFT + inter-token latency percentiles.

    PYTHONPATH=src python benchmarks/bench_latency.py [--out BENCH_serve.json]

Drives the streaming front-end (`repro.serve.server.StreamingServer`) the way
a population of independent users would: request arrivals are a Poisson
process (exponential gaps at `--rate` req/s), submitted **open-loop** — the
generator never waits for a response before sending the next request, so
queueing delay shows up in the measurements instead of silently throttling
the offered load (closed-loop load-gen's coordinated-omission trap).

Two sub-scenarios, written into the ``poisson_load`` section of
``BENCH_serve.json`` (merged into the existing report; CI-gated for
structure + finite/positive p99 TTFT by ``scripts/check_bench_json.py``):

* **steady** (top-level fields) — offered load below the engine's capacity:
  p50/p99 time-to-first-token (arrival -> first sampled token, queueing
  included) and inter-token latency (gap between consecutive sampled tokens
  of one request), plus throughput and the energy-conservation check
  (per-request incl. partials + idle == engine total).
* **overload** — offered load far above capacity with a small bounded
  admission queue and a per-request deadline: demonstrates backpressure
  (``RejectedError`` sheds load at submit) and deadline timeouts
  (``done_reason="timeout"`` partials), the service-level behavior the
  energy numbers are only meaningful alongside.

Latency numbers are wall-clock and machine-dependent (CI never gates them);
the structural invariants — first tokens stream before co-tenants retire,
cancelled/timed-out partials conserve energy — are what the checker and the
tier-1 suite pin down.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest
from repro.serve.scheduler import RejectedError
from repro.serve.server import StreamingServer


def _pct_ms(xs):
    """{p50, p99, mean, max, n} over a list of seconds, reported in ms."""
    if not xs:
        return {"p50": None, "p99": None, "mean": None, "max": None, "n": 0}
    ms = np.asarray(xs, np.float64) * 1e3
    return {"p50": round(float(np.percentile(ms, 50)), 3),
            "p99": round(float(np.percentile(ms, 99)), 3),
            "mean": round(float(ms.mean()), 3),
            "max": round(float(ms.max()), 3),
            "n": int(ms.size)}


def _warmup(eng, cfg, rng, prompt_lo, prompt_hi, max_new, batch):
    """Compile every step the timed run can touch, then reset the counters.

    The logical-view bucket is jit-static, so decode recompiles per pow2
    bucket: a lockstep batch of max-length prompts only ever decodes at the
    deepest bucket.  Drain a short request *alone* first so the small-bucket
    chunk/decode steps compile too — otherwise the measured run's first
    short request pays a multi-second compile that shows up as an 8s
    inter-token gap."""
    eng.submit(GenRequest(
        prompt=rng.integers(0, cfg.vocab_size, prompt_lo).astype(np.int32),
        max_new=max_new, seed=999))
    eng.drain()
    for i in range(batch):
        eng.submit(GenRequest(
            prompt=rng.integers(0, cfg.vocab_size, prompt_hi).astype(np.int32),
            max_new=max_new, seed=1000 + i))
    eng.drain()
    eng._steps = 0
    eng.total_energy_pj = 0.0
    eng.idle_energy_pj = 0.0
    eng.corner_energy_pj = {}
    eng.peak_concurrent = 0
    eng.kv_reads_total = 0.0
    eng.prefill_tokens_total = 0
    eng.cached_prefix_tokens = 0


def run_poisson(cfg, params, *, rate_rps, n_requests, prompt_lo=6,
                prompt_hi=20, max_new=12, batch=4, max_len=64, block_size=8,
                max_pending=16, deadline_s=None, seed=0):
    """One open-loop Poisson run on a fresh paged engine; returns metrics."""
    eng = ServingEngine(cfg, params, batch_size=batch, max_len=max_len,
                        seed=7, fresh_noise=False, paged=True,
                        block_size=block_size)
    rng = np.random.default_rng(seed)
    _warmup(eng, cfg, rng, prompt_lo, prompt_hi, max_new, batch)

    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    handles, rejected = [], 0
    with StreamingServer(eng, max_pending=max_pending) as srv:
        t0 = time.monotonic()
        for i, at in enumerate(arrivals):
            delay = t0 + at - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            prompt = rng.integers(
                0, cfg.vocab_size,
                int(rng.integers(prompt_lo, prompt_hi + 1))).astype(np.int32)
            try:
                handles.append(srv.submit(
                    GenRequest(prompt=prompt, max_new=max_new, seed=i),
                    deadline_s=deadline_s))
            except RejectedError:
                rejected += 1
        results = [h.result(timeout=600) for h in handles]
        wall = time.monotonic() - t0

    reasons = Counter(r.done_reason for r in results)
    toks = sum(len(r.tokens) for r in results)
    # conservation incl. cancelled/timed-out partials: every result carries
    # the energy already billed to it, idle waste stays with the engine
    billed = sum(r.energy_pj for r in results)
    conserved = bool(np.isclose(billed + eng.idle_energy_pj,
                                eng.total_energy_pj, rtol=1e-6))
    ttft = [h.ttft_s for h in handles if h.ttft_s is not None]
    itl = [d for h in handles for d in h.itl_s]
    return {
        "offered_rate_rps": rate_rps,
        "n_requests": n_requests,
        "batch": batch, "max_len": max_len, "block_size": block_size,
        "prompt_len": [prompt_lo, prompt_hi], "max_new": max_new,
        "max_pending": max_pending, "deadline_s": deadline_s,
        "submitted": len(handles), "rejected": rejected,
        "done_reasons": dict(sorted(reasons.items())),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "throughput_tok_per_s": round(toks / wall, 2) if wall else None,
        "peak_concurrent": eng.peak_concurrent,
        "ttft_ms": _pct_ms(ttft),
        "inter_token_ms": _pct_ms(itl),
        "total_uj": round(billed * 1e-6, 4),
        "idle_uj": round(eng.idle_energy_pj * 1e-6, 4),
        "energy_conserved_with_partials": conserved,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="analog")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="steady-state offered Poisson arrival rate (req/s) "
                         "— keep below the engine's capacity (~1.2 req/s for "
                         "the smoke config at max_new=12 on one CPU) so the "
                         "steady section measures service, not saturation "
                         "queueing; the overload sub-scenario covers the "
                         "burst case")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="merged into this report under 'poisson_load'")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink for the CI bench-smoke job (fail on "
                         "exceptions and structure, not on numbers)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.max_new = min(args.max_new, 6)
        args.rate = min(args.rate, 20.0)

    cfg = get_config(args.arch, emt_mode=args.mode, smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))

    section = run_poisson(cfg, params, rate_rps=args.rate,
                          n_requests=args.requests, max_new=args.max_new,
                          batch=args.batch)
    # overload: a near-burst (mean gap 2ms — far inside one engine step, so
    # arrivals outpace retirements on any machine; with warmup removing the
    # compile stalls, capacity-relative multipliers like "8x steady" turned
    # out NOT to overload a fast host) into a 4-deep admission queue —
    # backpressure rejections, and deadline timeouts for whatever queues,
    # are the *expected* outcome here
    section["overload"] = run_poisson(
        cfg, params, rate_rps=500.0, n_requests=32, max_new=args.max_new,
        batch=args.batch, max_pending=4, deadline_s=0.75, seed=1)

    report = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            report = json.load(f)
    report["poisson_load"] = section
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({"poisson_load": section}, indent=2))


if __name__ == "__main__":
    main()
