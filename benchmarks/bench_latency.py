"""Open-loop Poisson load generator: TTFT + inter-token latency percentiles.

    PYTHONPATH=src python benchmarks/bench_latency.py [--out BENCH_serve.json]

Drives the streaming front-end (`repro.serve.server.StreamingServer`) the way
a population of independent users would: request arrivals are a Poisson
process (exponential gaps at `--rate` req/s), submitted **open-loop** — the
generator never waits for a response before sending the next request, so
queueing delay shows up in the measurements instead of silently throttling
the offered load (closed-loop load-gen's coordinated-omission trap).

Two sub-scenarios, written into the ``poisson_load`` section of
``BENCH_serve.json`` (merged into the existing report; CI-gated for
structure + finite/positive p99 TTFT by ``scripts/check_bench_json.py``):

* **steady** (top-level fields) — offered load below the engine's capacity:
  p50/p99 time-to-first-token (arrival -> first sampled token, queueing
  included) and inter-token latency (gap between consecutive sampled tokens
  of one request), plus throughput and the energy-conservation check
  (per-request incl. partials + idle == engine total).
* **overload** — offered load far above capacity with a small bounded
  admission queue and a per-request deadline: demonstrates backpressure
  (``RejectedError`` sheds load at submit) and deadline timeouts
  (``done_reason="timeout"`` partials), the service-level behavior the
  energy numbers are only meaningful alongside.

Latency numbers are wall-clock and machine-dependent (CI never gates them);
the structural invariants — first tokens stream before co-tenants retire,
cancelled/timed-out partials conserve energy — are what the checker and the
tier-1 suite pin down.

``--multihost`` instead runs the data-parallel weak-scaling comparison
(docs/serving.md "Multi-device serving"): one child process per device count
(1/2/4 simulated via ``--xla_force_host_platform_device_count``), each
serving the same deterministic open-burst workload through the streaming
front-end on an ``n_shards = n_devices`` engine, written into the
``multihost`` section — token identity across device counts, per-shard
energy-ledger conservation, occupancy balance, and the 4-device decode
speedup are gated by ``scripts/check_bench_json.py`` (the speedup bound
conditions on the recorded ``host_cpus``: a 1-core host serializes the
per-device programs, capping wall-clock scaling near 1x by physics).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import Counter

import jax
import numpy as np

from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import GenRequest, view_bucket
from repro.serve.scheduler import RejectedError
from repro.serve.server import StreamingServer
from repro.serve.spec import ServeSpec


def _pct_ms(xs):
    """{p50, p99, mean, max, n} over a list of seconds, reported in ms."""
    if not xs:
        return {"p50": None, "p99": None, "mean": None, "max": None, "n": 0}
    ms = np.asarray(xs, np.float64) * 1e3
    return {"p50": round(float(np.percentile(ms, 50)), 3),
            "p99": round(float(np.percentile(ms, 99)), 3),
            "mean": round(float(ms.mean()), 3),
            "max": round(float(ms.max()), 3),
            "n": int(ms.size)}


def _warmup(eng, cfg, rng, prompt_lo, prompt_hi, max_new, batch):
    """Compile every step the timed run can touch, then reset the counters.

    The logical-view bucket is jit-static, so decode recompiles per pow2
    bucket: a lockstep batch of max-length prompts only ever decodes at the
    deepest bucket.  Drain a short request *alone* first so the small-bucket
    chunk/decode steps compile too — otherwise the measured run's first
    short request pays a multi-second compile that shows up as an 8s
    inter-token gap.

    Sharded engines (``n_shards > 1``) additionally drain one short request
    *per shard*: a single warmup request lands on one shard only, and the
    SPMD step's static ``view_len`` is the max over the per-shard buckets —
    so mixed occupancy patterns the measured run produces (one shard deep,
    the others shallow) would otherwise hit cold small-bucket compiles
    mid-measurement as phantom inter-token spikes."""
    eng.submit(GenRequest(
        prompt=rng.integers(0, cfg.vocab_size, prompt_lo).astype(np.int32),
        max_new=max_new, seed=999))
    eng.drain()
    if eng.n_shards > 1:
        for s in range(eng.n_shards):
            eng.submit(GenRequest(
                prompt=rng.integers(
                    0, cfg.vocab_size, prompt_lo).astype(np.int32),
                max_new=max_new, seed=900 + s))
        eng.drain()
    for i in range(batch):
        eng.submit(GenRequest(
            prompt=rng.integers(0, cfg.vocab_size, prompt_hi).astype(np.int32),
            max_new=max_new, seed=1000 + i))
    eng.drain()
    # backfill-at-depth sweep: pin one long request and admit a short one
    # every time the long one's position crosses into a new view bucket, so
    # the chunk (admission) step compiles at *every* bucket the measured run
    # can backfill into — a lockstep warmup wave admits everything at bucket
    # floor and would leave those compiles to land mid-measurement as
    # phantom multi-second inter-token spikes
    eng.submit(GenRequest(
        prompt=rng.integers(0, cfg.vocab_size, prompt_hi).astype(np.int32),
        max_new=max_new, seed=2000))
    seen, seed = set(), 2001
    while eng.scheduler.num_active or eng.scheduler.pending:
        need = 1 + max((s.pos for _, s in eng.scheduler.active_slots()),
                       default=0)
        b = view_bucket(need, eng.block_size, eng.max_len)
        if b not in seen:
            seen.add(b)
            eng.submit(GenRequest(
                prompt=rng.integers(0, cfg.vocab_size,
                                    prompt_lo).astype(np.int32),
                max_new=2, seed=seed))
            seed += 1
        eng.step()
    eng.drain()
    eng.reset_metrics()


def run_poisson(spec, cfg, params, *, rate_rps, n_requests, prompt_lo=6,
                prompt_hi=20, max_new=12, seed=0):
    """One open-loop Poisson run on a fresh paged engine built from `spec`
    (engine shape, admission bound, and deadline all come from the spec);
    returns metrics."""
    batch, max_len = spec.batch_size, spec.max_len
    block_size = spec.block_size
    max_pending, deadline_s = spec.max_pending, spec.deadline_s
    eng = spec.build_engine(cfg, params)
    rng = np.random.default_rng(seed)
    _warmup(eng, cfg, rng, prompt_lo, prompt_hi, max_new, batch)

    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    handles, rejected = [], 0
    with StreamingServer(eng, max_pending=max_pending) as srv:
        t0 = time.monotonic()
        for i, at in enumerate(arrivals):
            delay = t0 + at - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            prompt = rng.integers(
                0, cfg.vocab_size,
                int(rng.integers(prompt_lo, prompt_hi + 1))).astype(np.int32)
            try:
                handles.append(srv.submit(
                    GenRequest(prompt=prompt, max_new=max_new, seed=i),
                    deadline_s=deadline_s))
            except RejectedError:
                rejected += 1
        results = [h.result(timeout=600) for h in handles]
        wall = time.monotonic() - t0

    reasons = Counter(r.done_reason for r in results)
    toks = sum(len(r.tokens) for r in results)
    # conservation incl. cancelled/timed-out partials: every result carries
    # the energy already billed to it, idle waste stays with the engine
    billed = sum(r.energy_pj for r in results)
    conserved = eng.energy_conserved(results)
    ttft = [h.ttft_s for h in handles if h.ttft_s is not None]
    itl = [d for h in handles for d in h.itl_s]
    return {
        "offered_rate_rps": rate_rps,
        "n_requests": n_requests,
        "batch": batch, "max_len": max_len, "block_size": block_size,
        "prompt_len": [prompt_lo, prompt_hi], "max_new": max_new,
        "max_pending": max_pending, "deadline_s": deadline_s,
        "submitted": len(handles), "rejected": rejected,
        "done_reasons": dict(sorted(reasons.items())),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "throughput_tok_per_s": round(toks / wall, 2) if wall else None,
        "peak_concurrent": eng.peak_concurrent,
        "ttft_ms": _pct_ms(ttft),
        "inter_token_ms": _pct_ms(itl),
        "total_uj": round(billed * 1e-6, 4),
        "idle_uj": round(eng.idle_energy_pj * 1e-6, 4),
        "energy_conserved_with_partials": conserved,
    }


# -- multihost: 1 vs 2 vs 4 simulated devices --------------------------------
#
# `XLA_FLAGS=--xla_force_host_platform_device_count=N` must be set before jax
# initializes, so each device count runs in its own subprocess (spawned with
# the flag in its environment); the parent never touches jax for these runs.
# Weak scaling: the per-shard batch is fixed (`--batch`), so N devices serve
# an N-times larger decode batch — the throughput axis the data-parallel
# engine buys.  Every child serves the *same* deterministic workload with the
# per-row DAC scale + frozen noise, so the sharded runs must be
# token-identical to the single-device baseline at temperature 0 (gated by
# scripts/check_bench_json.py, like paged_vs_contiguous).

def run_multihost_child(args):
    """One device count, inside the XLA_FLAGS-forced subprocess: serve the
    fixed workload on an n-shard engine, print the metrics JSON on stdout."""
    n = args.multihost_child
    if jax.device_count() != n:
        raise SystemExit(f"multihost child expected {n} devices, got "
                         f"{jax.device_count()} — XLA_FLAGS not applied?")
    batch = args.batch * n
    # per-row DAC scale: co-tenant occupancy cannot perturb tokens, so the
    # sharded runs are comparable token-for-token with the baseline
    spec = ServeSpec(arch=args.arch, mode=args.mode, smoke=True,
                     a_per_row=True, batch_size=batch, max_len=64, seed=7,
                     frozen_noise=True, paged=True, block_size=8, shards=n)
    cfg = spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = spec.build_engine(cfg, params)
    rng = np.random.default_rng(0)
    _warmup(eng, cfg, rng, 6, 20, args.max_new, batch)

    wl = np.random.default_rng(42)     # same workload for every device count
    prompts = [wl.integers(0, cfg.vocab_size,
                           int(wl.integers(6, 21))).astype(np.int32)
               for _ in range(args.requests)]
    handles = []
    with StreamingServer(eng, max_pending=args.requests) as srv:
        t0 = time.monotonic()
        for i, p in enumerate(prompts):     # open burst: queueing included
            handles.append(srv.submit(
                GenRequest(prompt=p, max_new=args.max_new, seed=i)))
        results = [h.result(timeout=600) for h in handles]
        wall = time.monotonic() - t0

    results = sorted(results, key=lambda r: r.rid)
    toks = sum(len(r.tokens) for r in results)
    billed = sum(r.energy_pj for r in results)
    occ = eng.shard_occupancy
    shard_e, shard_idle = eng.shard_energy_pj, eng.shard_idle_energy_pj
    try:
        host_cpus = len(os.sched_getaffinity(0))
    except AttributeError:
        host_cpus = os.cpu_count() or 1
    out = {
        "n_devices": n, "n_shards": n, "batch": batch,
        # simulated devices share the host's cores: with host_cpus == 1 the
        # per-device programs serialize and wall-clock weak scaling is
        # physically capped near 1x — the checker conditions the speedup
        # gate on this (CI runners have >= 2)
        "host_cpus": host_cpus,
        "per_shard_batch": args.batch,
        "requests": len(results), "tokens": toks,
        "wall_s": round(wall, 3),
        "decode_tok_per_s": round(toks / wall, 2) if wall else None,
        "ttft_ms": _pct_ms([h.ttft_s for h in handles
                            if h.ttft_s is not None]),
        "inter_token_ms": _pct_ms([d for h in handles for d in h.itl_s]),
        "uj_per_token": round(eng.total_energy_pj * 1e-6 / max(toks, 1), 4),
        "total_uj": round(eng.total_energy_pj * 1e-6, 4),
        "idle_uj": round(eng.idle_energy_pj * 1e-6, 4),
        "shard_total_uj": [round(v * 1e-6, 4) for v in shard_e],
        "shard_idle_uj": [round(v * 1e-6, 4) for v in shard_idle],
        "shard_occupancy": occ.tolist(),
        # min/max shard step-occupancy: 1.0 = perfectly balanced admission
        "occupancy_balance": round(float(occ.min()) / max(float(occ.max()),
                                                          1.0), 4),
        "energy_conserved_with_partials": eng.energy_conserved(results),
        # the per-shard ledger split re-sums to the engine totals exactly
        "shard_split_conserved": bool(
            np.isclose(shard_e.sum(), eng.total_energy_pj, rtol=1e-9)
            and np.isclose(shard_idle.sum(), eng.idle_energy_pj, rtol=1e-9)),
        "token_ids": [list(map(int, r.tokens)) for r in results],
    }
    print(json.dumps(out))


def run_multihost(args):
    """Parent: spawn one child per device count, compare tokens, compute the
    4v1 weak-scaling speedup; returns the `multihost` report section."""
    import subprocess
    import sys

    devices = {}
    for n in (1, 2, 4):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        cmd = [sys.executable, os.path.abspath(__file__),
               "--multihost-child", str(n), "--arch", args.arch,
               "--mode", args.mode, "--requests", str(args.requests),
               "--max-new", str(args.max_new), "--batch", str(args.batch)]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise SystemExit(f"multihost child ({n} devices) failed:\n"
                             f"{proc.stdout}\n{proc.stderr}")
        devices[str(n)] = json.loads(proc.stdout.strip().splitlines()[-1])
        print(f"multihost: {n} device(s): "
              f"{devices[str(n)]['decode_tok_per_s']} tok/s", flush=True)

    base_tokens = devices["1"].pop("token_ids")
    section = {
        "workload": {"requests": args.requests, "max_new": args.max_new,
                     "per_shard_batch": args.batch, "prompt_len": [6, 20],
                     "quant": "a_per_row", "temperature": 0},
        "host_cpus": min(d["host_cpus"] for d in devices.values()),
        "devices": devices,
    }
    for k in ("2", "4"):
        section[f"token_identity_{k}v1"] = \
            devices[k].pop("token_ids") == base_tokens
    base = devices["1"]["decode_tok_per_s"]
    section["speedup_tok_per_s_4v1"] = \
        round(devices["4"]["decode_tok_per_s"] / base, 3) if base else None
    section["speedup_tok_per_s_2v1"] = \
        round(devices["2"]["decode_tok_per_s"] / base, 3) if base else None
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--mode", default="analog")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="steady-state offered Poisson arrival rate (req/s) "
                         "— keep below the engine's capacity (~1.2 req/s for "
                         "the smoke config at max_new=12 on one CPU) so the "
                         "steady section measures service, not saturation "
                         "queueing; the overload sub-scenario covers the "
                         "burst case")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="merged into this report under 'poisson_load'")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink for the CI bench-smoke job (fail on "
                         "exceptions and structure, not on numbers)")
    ap.add_argument("--multihost", action="store_true",
                    help="run the 1/2/4 simulated-device weak-scaling "
                         "comparison (subprocess per device count) and write "
                         "the 'multihost' section instead of 'poisson_load'")
    ap.add_argument("--multihost-child", type=int, default=None,
                    help=argparse.SUPPRESS)   # internal: one device count
    args = ap.parse_args()
    if args.multihost_child is not None:
        run_multihost_child(args)
        return
    if args.multihost:
        # decode-heavy workload: the weak-scaling claim is about decode
        # throughput, so decode steps must dominate the wall (short prompts,
        # long generations, enough requests for several baseline waves) and
        # the request count keeps the 4-device batch's last wave full
        args.requests = 32 if args.smoke else 48
        args.max_new = 16 if args.smoke else 24
    elif args.smoke:
        args.requests = min(args.requests, 8)
        args.max_new = min(args.max_new, 6)
        args.rate = min(args.rate, 20.0)

    if args.multihost:
        section = run_multihost(args)
        report = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                report = json.load(f)
        report["multihost"] = section
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(json.dumps({"multihost": section}, indent=2))
        return

    spec = ServeSpec(arch=args.arch, mode=args.mode, smoke=True,
                     batch_size=args.batch, max_len=64, seed=7,
                     frozen_noise=True, paged=True, block_size=8)
    cfg = spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))

    section = run_poisson(spec, cfg, params, rate_rps=args.rate,
                          n_requests=args.requests, max_new=args.max_new)
    # overload: a near-burst (mean gap 2ms — far inside one engine step, so
    # arrivals outpace retirements on any machine; with warmup removing the
    # compile stalls, capacity-relative multipliers like "8x steady" turned
    # out NOT to overload a fast host) into a 4-deep admission queue —
    # backpressure rejections, and deadline timeouts for whatever queues,
    # are the *expected* outcome here
    section["overload"] = run_poisson(
        spec.replace(max_pending=4, deadline_s=0.75), cfg, params,
        rate_rps=500.0, n_requests=32, max_new=args.max_new, seed=1)

    report = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            report = json.load(f)
    report["poisson_load"] = section
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps({"poisson_load": section}, indent=2))


if __name__ == "__main__":
    main()
