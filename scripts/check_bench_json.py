"""Validate the structure and invariants of the BENCH_*.json reports.

The CI bench-smoke / matrix-smoke jobs run the benchmark drivers in
``--smoke`` mode and then this checker.  A bench that crashes or silently
drops a scenario fails the job.  Raw wall numbers are mostly not gated (CI
runners are too noisy for tight thresholds — the checked-in reports carry
those), with one deliberate exception: the fused-decode vs clamped-gather
wall *ratio* at 100% occupancy is gated against a loose regression bound.
Both variants run in the same process seconds apart with interleaved
round-robin timing, so the ratio is far more stable than either wall time —
a breach means the one-launch fused path genuinely regressed relative to
the fallback it replaces (the checked-in BENCH_kernels.json holds the
tighter <= 1.05 acceptance number).

Structural byte invariants are exact and gated strictly: the prefill kernel
must move strictly fewer analytic K/V bytes than the legacy materialized
view in every benched case.

Checks are a **declarative gate registry**: ``@gate("section")`` registers
a checker that runs whenever that section appears in a report, so adding a
scenario means adding one gate function — not threading a new branch
through a monolithic ``check()``.  ``REQUIRED`` pins which sections each
report file must contain (a dropped scenario fails even if every present
section passes).

    python scripts/check_bench_json.py BENCH_serve.json BENCH_kernels.json
"""

import json
import math
import os
import sys

REQUIRED = {
    "BENCH_serve.json": [
        "lockstep",
        "staggered",
        "paged_vs_contiguous",
        "fused_paged",
        "mixed_placement",
        "shared_prefix",
        "poisson_load",
        "speculative",
        "multihost",
        "matrix",
    ],
    "BENCH_kernels.json": ["shape", "cases", "prefill_cases", "ratios"],
    # the standalone matrix-smoke artifact (benchmarks/matrix.py --smoke
    # writes only its own section when pointed at a fresh file)
    "BENCH_matrix.json": ["matrix"],
}

# loose-for-CI-noise regression bound on fused/gather_clamped at occ=100%
FUSED_RATIO_BOUND = 1.25

# multihost weak scaling: 4 simulated devices must reach >= 1.5x the
# single-device decode throughput — but only when the host actually has
# cores to run the per-device programs concurrently.  On a 1-core host the
# XLA CPU client serializes the four per-shard programs, so wall-clock
# weak scaling is physically capped near 1x; there the gate degrades to a
# sanity floor (sharding must not collapse throughput).
MULTIHOST_SPEEDUP_BOUND = 1.5
MULTIHOST_SINGLE_CORE_FLOOR = 0.8
MULTIHOST_BALANCE_BOUND = 0.5

GATES = {}


def gate(section):
    """Register ``fn(path, payload, report)`` as the checker for a report
    section.  The function runs whenever `section` is present; it fails the
    job by raising SystemExit.  One gate per section (re-registering is a
    programming error, not an override)."""
    def deco(fn):
        if section in GATES:
            raise ValueError(f"gate {section!r} registered twice")
        GATES[section] = fn
        return fn
    return deco


@gate("shared_prefix")
def check_shared_prefix(path, shared, report=None):
    """Prefix-cache section (bench_serve.py / matrix cells): the paged
    cache-on/off runs must stay token-identical to the contiguous engine."""
    if not shared.get("token_identity_paged_vs_contiguous", False):
        raise SystemExit(f"{path}: shared_prefix broke token identity")


@gate("poisson_load")
def check_poisson(path, poisson, report=None):
    """Latency section (bench_latency.py): the percentile fields must exist
    and the steady-state p99 TTFT / inter-token latency must be finite and
    positive (raw magnitudes are machine-dependent and never gated).  The
    energy-conservation invariant must hold including cancelled/timed-out
    partials, and the overload sub-scenario must actually exercise
    backpressure or deadlines (otherwise the front-end silently queued
    unbounded)."""
    for field in ("ttft_ms", "inter_token_ms"):
        stats = poisson.get(field)
        if not isinstance(stats, dict):
            raise SystemExit(f"{path}: poisson_load missing {field}")
        for pct in ("p50", "p99"):
            v = stats.get(pct)
            if v is None or not math.isfinite(v) or v <= 0:
                raise SystemExit(
                    f"{path}: poisson_load {field}.{pct} must be finite "
                    f"and positive, got {v!r}")
    if not poisson.get("energy_conserved_with_partials", False):
        raise SystemExit(f"{path}: poisson_load broke per-request + idle "
                         f"== total energy conservation")
    over = poisson.get("overload")
    if over is not None:
        shed = (over.get("rejected", 0)
                + over.get("done_reasons", {}).get("timeout", 0)
                + over.get("done_reasons", {}).get("cancelled", 0))
        if shed <= 0:
            raise SystemExit(
                f"{path}: poisson_load overload shed no load — backpressure "
                f"or deadline enforcement is broken")
        if not over.get("energy_conserved_with_partials", False):
            raise SystemExit(f"{path}: poisson_load overload broke energy "
                             f"conservation with partials")


@gate("speculative")
def check_speculative(path, spec, report=None):
    """Speculative-decoding section (bench_speculative.py).  Gated hard:
    these are deterministic quantities (frozen noise, exact energy
    arithmetic), not wall numbers.  The accept rate must be a real rate in
    (0, 1]; the draft + target energy split must sum to the run's total
    (the two-placement ledger is one ledger); token identity and energy
    conservation must hold; and — the paper-facing claim — at accept rate
    >= 0.5 speculation must record strictly lower analog-corner uJ/token
    than the non-speculative baseline."""
    ar = spec.get("accept_rate")
    if not (isinstance(ar, (int, float)) and 0.0 < ar <= 1.0):
        raise SystemExit(f"{path}: speculative accept_rate must be in "
                         f"(0, 1], got {ar!r}")
    hist = spec.get("accept_len_hist")
    if not (isinstance(hist, list) and hist and sum(hist) > 0
            and all(isinstance(v, int) and v >= 0 for v in hist)):
        raise SystemExit(f"{path}: speculative accept_len_hist must be a "
                         f"non-empty histogram, got {hist!r}")
    for flag in ("token_identity", "energy_conserved"):
        if not spec.get(flag, False):
            raise SystemExit(f"{path}: speculative {flag} is false — "
                             f"speculation changed tokens or broke the "
                             f"energy ledger")
    draft = spec.get("draft_energy_uj")
    target = spec.get("target_energy_uj")
    total = spec.get("total_energy_uj")
    for name, v in (("draft", draft), ("target", target), ("total", total)):
        if not (isinstance(v, (int, float)) and math.isfinite(v) and v >= 0):
            raise SystemExit(f"{path}: speculative {name}_energy_uj must be "
                             f"finite and >= 0, got {v!r}")
    if abs(draft + target - total) > 1e-4 * max(total, 1e-12):
        raise SystemExit(f"{path}: speculative draft + target energy "
                         f"({draft} + {target}) != total ({total})")
    improv = spec.get("analog_uj_per_token_improvement")
    if not (isinstance(improv, (int, float)) and math.isfinite(improv)):
        raise SystemExit(f"{path}: speculative analog_uj_per_token_"
                         f"improvement missing or non-finite: {improv!r}")
    if ar >= 0.5 and improv <= 0:
        raise SystemExit(
            f"{path}: speculation recorded NO analog energy win "
            f"(improvement {improv} uJ/token at accept rate {ar}) — the "
            f"verify chunk stopped amortizing the static macro cost")


@gate("multihost")
def check_multihost(path, mh, report=None):
    """Data-parallel serving section (bench_latency.py --multihost).  The
    deterministic claims are gated hard: sharded runs must be token-identical
    to the single-device baseline at temperature 0, every device count must
    conserve energy including the per-shard ledger split, and 4-device
    admission must stay occupancy-balanced.  The weak-scaling speedup is
    gated at MULTIHOST_SPEEDUP_BOUND when the host has >= 2 cores (CI); on a
    1-core host only the serialization sanity floor applies."""
    devices = mh.get("devices")
    if not isinstance(devices, dict):
        raise SystemExit(f"{path}: multihost missing devices map")
    for n in ("1", "2", "4"):
        d = devices.get(n)
        if not isinstance(d, dict):
            raise SystemExit(f"{path}: multihost missing devices[{n!r}]")
        for field in ("decode_tok_per_s", "wall_s", "uj_per_token",
                      "total_uj", "idle_uj", "ttft_ms", "inter_token_ms"):
            v = d.get(field)
            if isinstance(v, dict):
                v = v.get("p50")
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v > 0):
                raise SystemExit(f"{path}: multihost devices[{n!r}].{field} "
                                 f"must be finite and positive, got {v!r}")
        if d.get("n_shards") != int(n):
            raise SystemExit(f"{path}: multihost devices[{n!r}] ran "
                             f"{d.get('n_shards')!r} shards, expected {n}")
        for field in ("shard_total_uj", "shard_idle_uj", "shard_occupancy"):
            v = d.get(field)
            if not (isinstance(v, list) and len(v) == int(n)):
                raise SystemExit(f"{path}: multihost devices[{n!r}].{field} "
                                 f"must have one entry per shard, got {v!r}")
        for flag in ("energy_conserved_with_partials",
                     "shard_split_conserved"):
            if not d.get(flag, False):
                raise SystemExit(f"{path}: multihost devices[{n!r}] broke "
                                 f"{flag} — the per-shard energy ledger no "
                                 f"longer re-sums to the engine totals")
    for flag in ("token_identity_2v1", "token_identity_4v1"):
        if not mh.get(flag, False):
            raise SystemExit(f"{path}: multihost {flag} is false — sharded "
                             f"decode changed tokens vs the single-device "
                             f"baseline at temperature 0")
    bal = devices["4"].get("occupancy_balance")
    if not (isinstance(bal, (int, float)) and bal >= MULTIHOST_BALANCE_BOUND):
        raise SystemExit(
            f"{path}: multihost 4-device occupancy_balance {bal!r} < "
            f"{MULTIHOST_BALANCE_BOUND} — slot-to-shard admission is "
            f"starving a shard")
    speedup = mh.get("speedup_tok_per_s_4v1")
    if not (isinstance(speedup, (int, float)) and math.isfinite(speedup)):
        raise SystemExit(f"{path}: multihost speedup_tok_per_s_4v1 missing "
                         f"or non-finite: {speedup!r}")
    host_cpus = mh.get("host_cpus", 1)
    if host_cpus >= 2:
        if speedup < MULTIHOST_SPEEDUP_BOUND:
            raise SystemExit(
                f"{path}: multihost 4-device decode speedup {speedup} < "
                f"{MULTIHOST_SPEEDUP_BOUND} on a {host_cpus}-core host — "
                f"data-parallel serving stopped weak-scaling")
    elif speedup < MULTIHOST_SINGLE_CORE_FLOOR:
        raise SystemExit(
            f"{path}: multihost 4-device decode speedup {speedup} < "
            f"serialization floor {MULTIHOST_SINGLE_CORE_FLOOR} on a 1-core "
            f"host — sharding overhead collapsed throughput")


@gate("matrix")
def check_matrix(path, m, report=None):
    """Scenario-matrix frontier section (benchmarks/matrix.py).  Gated:

    * every cell conserves energy (per-request + idle == total, partials
      included) and carries finite positive throughput/energy metrics; the
      accuracy proxy, when present, is a real accuracy in [0, 1];
    * every identity group is token-identical (cells differing only along
      the matrix's identity axes must decode the same tokens);
    * the stored Pareto frontier matches a recomputation from the cells
      (per EMT-surface group, none empty) — a stale or hand-edited
      frontier fails, which is what makes the checked-in report's frontier
      reviewable as the non-regression baseline;
    * the legacy sections re-emitted from matrix cells pass the original
      scenarios' gates, and at a >= 50% shared prefix the prefix cache must
      still strictly reduce prefill tokens and uJ/token.
    """
    cells = m.get("cells")
    if not (isinstance(cells, list) and cells):
        raise SystemExit(f"{path}: matrix has no cells")
    for c in cells:
        cn = c.get("name", "?")
        if not c.get("energy_conserved", False):
            raise SystemExit(f"{path}: matrix cell {cn} broke per-request "
                             f"+ idle == total energy conservation")
        if c.get("token_identity") is False:
            raise SystemExit(f"{path}: matrix cell {cn} broke token "
                             f"identity within its identity group")
        for field in ("decode_tok_per_s", "uj_per_token"):
            v = c.get(field)
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v > 0):
                raise SystemExit(f"{path}: matrix cell {cn}.{field} must be "
                                 f"finite and positive, got {v!r}")
        acc = c.get("accuracy_proxy")
        if acc is not None and not (isinstance(acc, (int, float))
                                    and 0.0 <= acc <= 1.0):
            raise SystemExit(f"{path}: matrix cell {cn}.accuracy_proxy must "
                             f"be in [0, 1], got {acc!r}")
    for label, g in m.get("identity", {}).items():
        if not g.get("identical", False):
            raise SystemExit(f"{path}: matrix identity group {label!r} is "
                             f"not token-identical: {g.get('cells')}")
    frontier = m.get("frontier", {})
    groups = frontier.get("groups")
    if not isinstance(groups, dict) or not groups:
        raise SystemExit(f"{path}: matrix frontier has no groups")
    for label, g in groups.items():
        if not g.get("pareto"):
            raise SystemExit(f"{path}: matrix frontier group {label!r} has "
                             f"an empty Pareto set")
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    from repro.analysis.frontier import frontier_report
    recomputed = frontier_report(cells)["pareto_names"]
    if recomputed != frontier.get("pareto_names"):
        raise SystemExit(
            f"{path}: matrix frontier is stale — stored Pareto set "
            f"{frontier.get('pareto_names')} != recomputed {recomputed} "
            f"from the cell metrics")
    legacy = m.get("legacy", {})
    if "poisson_load" in legacy:
        check_poisson(path, legacy["poisson_load"], report)
    sp = legacy.get("shared_prefix")
    if sp is not None:
        check_shared_prefix(path, sp, report)
        if sp.get("shared_fraction", 0) >= 0.5:
            for field in ("prefill_tokens_ratio", "uj_per_token_ratio"):
                v = sp.get(field)
                if not (isinstance(v, (int, float)) and v > 1.0):
                    raise SystemExit(
                        f"{path}: matrix shared-prefix cell stopped saving "
                        f"— {field} {v!r} <= 1.0 at a "
                        f"{sp['shared_fraction']:.0%} shared prefix")


@gate("ratios")
def check_kernel_ratios(path, ratios, report=None):
    """Fused one-launch decode vs the clamped-gather fallback it replaced:
    the interleaved wall ratio at 100% occupancy is gated loosely (see
    module docstring)."""
    ratio = ratios["fused_vs_gather_clamped"]["occ100_max"]
    if ratio > FUSED_RATIO_BOUND:
        raise SystemExit(
            f"{path}: fused decode regressed — fused/gather_clamped at "
            f"100% occupancy is {ratio} > bound {FUSED_RATIO_BOUND}")


@gate("prefill_cases")
def check_prefill_bytes(path, prefill_cases, report=None):
    """Analytic K/V byte invariant: the prefill kernel must move strictly
    fewer bytes than the legacy materialized view in every case."""
    for c in prefill_cases:
        moved = c["kv_bytes_moved"]
        if moved["kernel"] >= moved["legacy_gather"]:
            raise SystemExit(
                f"{path}: prefill kernel must move strictly fewer K/V "
                f"bytes than the materialized view: {c}")


def check(path):
    with open(path) as f:
        report = json.load(f)
    name = path.rsplit("/", 1)[-1]
    missing = [k for k in REQUIRED.get(name, []) if k not in report]
    if missing:
        raise SystemExit(f"{path}: missing scenarios {missing}")
    ran = [section for section, payload in report.items()
           if section in GATES and GATES[section](path, payload, report)
           is None]
    print(f"{path}: ok ({len(report)} sections; gated: "
          f"{', '.join(ran) or 'none'})")


if __name__ == "__main__":
    for arg in sys.argv[1:]:
        check(arg)
