"""Validate the structure of the BENCH_*.json reports.

The CI bench-smoke job runs the benchmark drivers in `--smoke` mode and then
this checker: a bench that crashes or silently drops a scenario fails the
job, while the numbers themselves are never gated (CI runners are too noisy
for thresholds — the checked-in reports carry those).

    python scripts/check_bench_json.py BENCH_serve.json BENCH_kernels.json
"""

import json
import sys

REQUIRED = {
    "BENCH_serve.json": [
        "lockstep",
        "staggered",
        "paged_vs_contiguous",
        "fused_paged",
        "mixed_placement",
        "shared_prefix",
    ],
    "BENCH_kernels.json": ["shape", "cases"],
}


def check(path):
    with open(path) as f:
        report = json.load(f)
    name = path.rsplit("/", 1)[-1]
    missing = [k for k in REQUIRED.get(name, []) if k not in report]
    if missing:
        raise SystemExit(f"{path}: missing scenarios {missing}")
    shared = report.get("shared_prefix")
    if shared is not None:
        if not shared.get("token_identity_paged_vs_contiguous", False):
            raise SystemExit(f"{path}: shared_prefix broke token identity")
    print(f"{path}: ok ({len(report)} sections)")


if __name__ == "__main__":
    for arg in sys.argv[1:]:
        check(arg)
