"""Validate the structure and invariants of the BENCH_*.json reports.

The CI bench-smoke job runs the benchmark drivers in `--smoke` mode and then
this checker.  A bench that crashes or silently drops a scenario fails the
job.  Raw wall numbers are mostly not gated (CI runners are too noisy for
tight thresholds — the checked-in reports carry those), with one deliberate
exception: the fused-decode vs clamped-gather wall *ratio* at 100% occupancy
is gated against a loose regression bound.  Both variants run in the same
process seconds apart with interleaved round-robin timing, so the ratio is
far more stable than either wall time — a breach means the one-launch fused
path genuinely regressed relative to the fallback it replaces (the
checked-in BENCH_kernels.json holds the tighter <= 1.05 acceptance number).

Structural byte invariants are exact and gated strictly: the prefill kernel
must move strictly fewer analytic K/V bytes than the legacy materialized
view in every benched case.

    python scripts/check_bench_json.py BENCH_serve.json BENCH_kernels.json
"""

import json
import sys

REQUIRED = {
    "BENCH_serve.json": [
        "lockstep",
        "staggered",
        "paged_vs_contiguous",
        "fused_paged",
        "mixed_placement",
        "shared_prefix",
    ],
    "BENCH_kernels.json": ["shape", "cases", "prefill_cases", "ratios"],
}

# loose-for-CI-noise regression bound on fused/gather_clamped at occ=100%
FUSED_RATIO_BOUND = 1.25


def check(path):
    with open(path) as f:
        report = json.load(f)
    name = path.rsplit("/", 1)[-1]
    missing = [k for k in REQUIRED.get(name, []) if k not in report]
    if missing:
        raise SystemExit(f"{path}: missing scenarios {missing}")
    shared = report.get("shared_prefix")
    if shared is not None:
        if not shared.get("token_identity_paged_vs_contiguous", False):
            raise SystemExit(f"{path}: shared_prefix broke token identity")
    if name == "BENCH_kernels.json":
        ratio = report["ratios"]["fused_vs_gather_clamped"]["occ100_max"]
        if ratio > FUSED_RATIO_BOUND:
            raise SystemExit(
                f"{path}: fused decode regressed — fused/gather_clamped at "
                f"100% occupancy is {ratio} > bound {FUSED_RATIO_BOUND}")
        for c in report["prefill_cases"]:
            moved = c["kv_bytes_moved"]
            if moved["kernel"] >= moved["legacy_gather"]:
                raise SystemExit(
                    f"{path}: prefill kernel must move strictly fewer K/V "
                    f"bytes than the materialized view: {c}")
        print(f"{path}: ok ({len(report['cases'])} decode + "
              f"{len(report['prefill_cases'])} prefill cases, "
              f"fused ratio {ratio} <= {FUSED_RATIO_BOUND})")
        return
    print(f"{path}: ok ({len(report)} sections)")


if __name__ == "__main__":
    for arg in sys.argv[1:]:
        check(arg)
