#!/usr/bin/env bash
# Tier-1 verify suite: the fast tests (everything not marked `slow`), pinned
# behind the `tier1` marker so the verify command stays stable as slow suites
# grow. Usage: scripts/run_tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q -m tier1 "$@"
