"""Streaming serving example: per-token delivery, cancellation, deadlines.

    PYTHONPATH=src python examples/stream_lm.py

Starts the async front-end (`repro.serve.server.StreamingServer`) over a
paged continuous-batching engine and shows the request lifecycle a real
client sees:

* two co-tenant requests stream their tokens **as they are sampled** — the
  printout interleaves, and both first tokens arrive long before either
  request retires;
* a third request is cancelled mid-stream: it retires immediately with
  `done_reason="cancelled"`, keeps the energy already billed to it (the
  per-request + idle == total invariant holds for partials), and its KV
  blocks go back to the pool;
* a fourth request carries a deadline it cannot meet and times out
  (`done_reason="timeout"`);
* a burst beyond the bounded admission queue is rejected with
  `RejectedError` (backpressure) instead of queueing unboundedly.
"""
import threading

import jax
import numpy as np

from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import GenRequest
from repro.serve.scheduler import RejectedError
from repro.serve.server import StreamingServer
from repro.serve.spec import ServeSpec


def main():
    spec = ServeSpec(arch="gemma3-1b", mode="analog", smoke=True,
                     batch_size=2, max_len=48, frozen_noise=True,
                     paged=True, block_size=8)
    cfg = spec.build_config()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mk = lambda n, **kw: GenRequest(  # noqa: E731
        prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32), **kw)

    eng = spec.build_engine(cfg, params)
    # warm the jit caches so streamed latencies are serving, not compiling
    eng.submit(mk(12, max_new=16))
    eng.drain()

    with StreamingServer(eng, max_pending=2) as srv:
        print("-- two co-tenant requests, tokens streamed as sampled --")
        h0 = srv.submit(mk(12, max_new=10, seed=1))
        h1 = srv.submit(mk(8, max_new=10, seed=2))

        def consume(tag, h):
            for tok in h.tokens(timeout=120):
                print(f"  {tag} -> {tok}")

        threads = [threading.Thread(target=consume, args=(f"req{i}", h))
                   for i, h in enumerate((h0, h1))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, h in enumerate((h0, h1)):
            r = h.result()
            print(f"req{i}: {r.done_reason}, {len(r.tokens)} tokens, "
                  f"TTFT {h.ttft_s * 1e3:.1f} ms")

        print("-- cancellation mid-stream --")
        h2 = srv.submit(mk(12, max_new=64, seed=3))
        for i, tok in enumerate(h2.tokens(timeout=120)):
            print(f"  req2 -> {tok}")
            if i == 2:
                h2.cancel()
        r2 = h2.result()
        print(f"req2: {r2.done_reason} after {len(r2.tokens)} tokens, "
              f"partial energy {r2.energy_pj * 1e-6:.4f} uJ still billed")

        print("-- deadline timeout --")
        h3 = srv.submit(mk(12, max_new=512), deadline_s=0.15)
        r3 = h3.result(timeout=120)
        print(f"req3: {r3.done_reason} with {len(r3.tokens)} tokens")

        print("-- backpressure: queue bound 2 --")
        burst, rejected = [], 0
        for i in range(8):
            try:
                burst.append(srv.submit(mk(8, max_new=24, seed=10 + i)))
            except RejectedError:
                rejected += 1
        for h in burst:
            h.result(timeout=120)
        print(f"accepted {len(burst)}, rejected {rejected} "
              f"(bounded admission queue)")
    print(f"server stats: {srv.stats}")


if __name__ == "__main__":
    main()
