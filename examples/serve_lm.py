"""Continuous-batching serving example: EMT execution variants side by side.

    PYTHONPATH=src python examples/serve_lm.py [--device CORNER]
    PYTHONPATH=src python examples/serve_lm.py --placement mixed

Submits staggered-arrival requests (one every other engine step, backfilling
slots mid-decode) to the same checkpoint under ideal / analog / bit-serial /
mixed-placement execution and reports tokens/s + per-request EMT energy in
uJ/token, demonstrating the paper's accuracy/energy/latency trade-off
(Table 1 structure) at serving time.  The engines run on the paged
block-table KV cache (block_size=8): requests hold only the blocks their
tokens occupy, so admission is gated on the free-block budget rather than
max_len-sized slots, and decode attends through the fused paged-attention
kernel (`--no-fused-paged-attn` falls back to the length-clamped gather;
the resolved per-layer attention path is printed at startup).

`--device` pins all layers to one registered technology corner; the default
`mixed` variant is a heterogeneous placement (analog attention on PCM,
bit-serial MLPs on RRAM — docs/device_models.md) whose resolved per-layer
plan and per-corner energy split are printed.
"""
import argparse
import time

import jax
import numpy as np

from repro.analysis.report import corner_table
from repro.launch.serve import print_plan, print_attn_paths
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import GenRequest
from repro.serve.spec import ServeSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--device", default=None,
                    help="single registered corner for the analog/bitserial "
                         "variants (pcm, rram, mlc2, mlc4, sram_digital)")
    ap.add_argument("--placement", default="mixed",
                    help="placement preset for the heterogeneous variant")
    ap.add_argument("--fused-paged-attn", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused paged-attention decode kernel (default on)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # the execution variants share one ServeSpec skeleton — only the EMT
    # surface (mode/device vs placement) differs per row
    base_spec = ServeSpec(arch="gemma2-9b", smoke=True, batch_size=2,
                          max_len=28, frozen_noise=True, paged=True,
                          block_size=8,
                          fused_paged_attn=args.fused_paged_attn)
    base = base_spec.replace(mode="ideal").build_config()
    params = init_params(lm.specs(base), jax.random.PRNGKey(0))
    prompts = [rng.integers(0, base.vocab_size, size=12).astype(np.int32)
               for _ in range(4)]

    results = {}
    for mode in ("ideal", "analog", "bitserial", "mixed"):
        if mode == "mixed":
            spec = base_spec.replace(placement=args.placement)
        else:
            spec = base_spec.replace(mode=mode, device=args.device)
        cfg = spec.build_config()
        if mode == "ideal":
            print_attn_paths(cfg)       # same resolution for every variant
        # ideal config has no rho params; analog/bitserial reuse ideal weights
        p = params if mode == "ideal" else init_params(
            lm.specs(cfg), jax.random.PRNGKey(0))
        if mode != "ideal":
            # copy shared weights from the ideal checkpoint (elastic graft)
            from repro.utils.pytrees import flatten_with_paths
            old = dict(flatten_with_paths(params))
            flat, treedef = jax.tree_util.tree_flatten_with_path(p)
            leaves = []
            for path, leaf in flat:
                key = "/".join(str(getattr(q, "key", q)) for q in path)
                leaves.append(old.get(key, leaf))
            p = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(p), leaves)
        # frozen noise: tokens depend only on the request, so the ideal-vs-
        # analog agreement below measures fluctuation, not seed drift
        eng = spec.build_engine(cfg, p)
        reqs = [GenRequest(prompt=pr, max_new=12) for pr in prompts]
        t0 = time.time()
        res = eng.serve(reqs, stagger=2)              # backfills mid-decode
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in res)
        uj_tok = sum(r.energy_pj for r in res) * 1e-6 / toks
        results[mode] = [r.tokens for r in res]
        free = eng.kv.pool_g.num_free
        print(f"[{mode:9s}] {toks/dt:6.1f} tok/s  {uj_tok:8.4f} uJ/token  "
              f"kv-blocks free={free}/{eng.kv.pool_g.num_blocks}  "
              f"sample={res[0].tokens[:6].tolist()}")
        if mode == "mixed":
            print_plan(cfg)
            print(corner_table(eng.corner_energy_pj, tokens=toks))

    # analog output should mostly agree with ideal at rho=4 (small fluctuation)
    agree = np.mean([np.mean(a == b) for a, b in
                     zip(results["ideal"], results["analog"])])
    print(f"ideal-vs-analog token agreement: {agree:.2f}")


if __name__ == "__main__":
    main()
