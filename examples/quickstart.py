"""Quickstart: train a tiny EMT-aware LM (techniques A+B), then serve it with
bit-serial decomposition (technique C) and compare energy.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.serve.engine import ServingEngine, GenRequest
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state


def main():
    # 1. a reduced gemma3-family config with analog EMT simulation (A + B)
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    print(f"model: {cfg.name}  (EMT mode={cfg.emt.mode}, rho0={cfg.emt.rho_init})")

    tcfg = TrainConfig(lam=1e-6, lr=1e-3, warmup=10, total_steps=60,
                       opt=OptimizerConfig(name="adamw"))
    step_fn, opt = make_train_step(cfg, tcfg, None, None)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)

    t0 = time.time()
    for s in range(60):
        state, m = jitted(state, data.batch_at(s))
        if s % 20 == 0 or s == 59:
            print(f"  step {s:3d}  ce={float(m['ce']):.3f} "
                  f"energy={float(m['energy_uj']):.2f}uJ "
                  f"rho={float(m['rho_mean']):.2f}")
    print(f"trained 60 steps in {time.time()-t0:.1f}s")

    # 2. serve it — analog (single-read) vs bit-serial decomposed (technique C)
    prompts = [np.arange(8, dtype=np.int32) + i for i in range(4)]
    for mode in ("analog", "bitserial"):
        scfg = get_config("gemma3-1b", emt_mode=mode, smoke=True)
        scfg = scfg.replace(dtype=jnp.float32)
        eng = ServingEngine(scfg, state["params"], batch_size=4, max_len=24)
        outs, energy = eng.generate(
            [GenRequest(prompt=p, max_new=8) for p in prompts])
        print(f"serve[{mode:9s}]  tokens={outs[0][:8].tolist()}  "
              f"energy={energy*1e-6:.3f}uJ")
    print("technique C uses less energy per token (Eq. 20) at higher latency.")


if __name__ == "__main__":
    main()
