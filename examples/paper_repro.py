"""Paper-faithful reproduction driver (Figs. 7/9/10 in one run).

    PYTHONPATH=src python examples/paper_repro.py [--steps 150]

Trains the paper's CNN family under the four regimes (traditional / A / A+B /
A+B+C) on the synthetic image task, evaluates each deployed on simulated EMT,
and prints the Fig. 9-style comparison plus the Fig. 10 robustness sweep.
"""
import argparse

from benchmarks.ablation_lib import run_method
from repro.configs.paper_cnn import vgg_small, resnet_small


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=220)
    args = ap.parse_args()

    print("== Fig. 9 ablation (vgg family, synthetic images) ==")
    print(f"{'method':12s} {'acc':>6s} {'energy_uJ':>10s} {'rho':>6s}")
    rows = {}
    for method, kw in [("traditional", dict(rho=4.0, eval_rho=4.0)),
                       ("A", dict(rho=4.0)),
                       ("A+B", dict(rho=4.0, lam=3e-8)),
                       ("A+B+C", dict(rho=4.0, lam=3e-8))]:
        r = run_method(vgg_small(), method, steps=args.steps, **kw)
        rows[method] = r
        print(f"{method:12s} {r['acc']:6.3f} {r['energy_uj']:10.4f} "
              f"{r['rho']:6.2f}")
    print(f"-> A+B+C energy reduction vs A+B: "
          f"{rows['A+B']['energy_uj']/max(rows['A+B+C']['energy_uj'],1e-9):.1f}x "
          f"(paper: ~1 order of magnitude, Table 1)")

    print("\n== Fig. 10 robustness (resnet family) ==")
    for intensity in ("weak", "normal", "strong"):
        r = run_method(resnet_small(), "A+B", rho=4.0, lam=3e-8,
                       steps=args.steps // 2, intensity=intensity)
        print(f"intensity={intensity:7s} acc={r['acc']:.3f} "
              f"energy={r['energy_uj']:.4f}uJ rho={r['rho']:.2f}")


if __name__ == "__main__":
    main()
