"""End-to-end driver: train a ~100M-parameter EMT-aware LM for a few hundred
steps with the fault-tolerant loop (checkpoint/resume, watchdog, async saves).

    PYTHONPATH=src python examples/train_lm.py --preset full   # ~100M params
    PYTHONPATH=src python examples/train_lm.py --preset small  # CPU-friendly

The `full` preset is the deliverable configuration (100M, a few hundred steps);
on a TPU slice it runs in minutes. On this CPU-only box use `small` (same code
path, ~8M params) or set --steps down. Progress/metrics stream to JSONL; kill
-TERM the process to watch the preemption-safe checkpoint kick in, re-run to
resume.
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs.common import emt_preset
from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state
from repro.train.loop import LoopConfig, train_loop

PRESETS = {
    # ~103M params: 12L x d768 x ff2048, 32k vocab
    "full": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=32768, batch=16, seq=512),
    # ~3M params: CPU-friendly, same family
    "small": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=512, vocab_size=512, batch=8, seq=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lam", type=float, default=1e-6)
    ap.add_argument("--emt-mode", default="analog")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        dtype=jnp.float32, emt=emt_preset(args.emt_mode), remat=False)

    from repro.models import lm as lmod
    from repro.nn.param import abstract_params
    from repro.utils import tree_param_count
    n = tree_param_count(abstract_params(lmod.specs(cfg)))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, EMT={args.emt_mode}, "
          f"steps={args.steps}")

    tcfg = TrainConfig(lam=args.lam, lr=2e-3, warmup=max(10, args.steps // 20),
                       total_steps=args.steps,
                       opt=OptimizerConfig(name="adamw"))
    step_fn, opt = make_train_step(cfg, tcfg, None, None)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=p["seq"],
                       batch_size=p["batch"])

    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10,
                      metrics_path=os.path.join(args.ckpt_dir,
                                                "metrics.jsonl"))
    state, history = train_loop(state, jitted, data.batch_at, lcfg)
    if len(history) >= 2:
        print(f"[train_lm] ce {history[0]['ce']:.3f} -> {history[-1]['ce']:.3f} "
              f"(energy {history[-1]['energy_uj']:.1f} uJ/step, "
              f"rho {history[-1]['rho_mean']:.2f})")


if __name__ == "__main__":
    main()
