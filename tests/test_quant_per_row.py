"""Per-row activation (DAC) quantization scale — the fix for the ROADMAP
"Known subtlety": the per-tensor DAC scale couples co-tenant batch rows at the
LSB, so analog-mode token streams are occupancy-sensitive and cache
equivalences only hold at matched admission schedules.  With
``QuantConfig(a_per_row=True)`` every token gets its own row scale and analog
paged-vs-contiguous identity holds under *mismatched* admission schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.emt_linear import EMTConfig, emt_dense, dense_specs
from repro.core.quant import QuantConfig, quant_levels
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest


def test_quant_levels_per_row_scale_is_row_local():
    x = np.array([[0.5, -0.25, 0.125], [8.0, 2.0, -4.0]], np.float32)
    lv, scale = quant_levels(jnp.asarray(x), 8, axis=-1)
    assert scale.shape == (2, 1)
    # scaling one row must not move the other row's levels
    x2 = x.copy()
    x2[1] *= 100.0
    lv2, _ = quant_levels(jnp.asarray(x2), 8, axis=-1)
    np.testing.assert_array_equal(np.asarray(lv[0]), np.asarray(lv2[0]))
    # per-tensor couples them
    lv_t, scale_t = quant_levels(jnp.asarray(x), 8, axis=None)
    lv_t2, _ = quant_levels(jnp.asarray(x2), 8, axis=None)
    assert scale_t.shape == ()
    assert not np.array_equal(np.asarray(lv_t[0]), np.asarray(lv_t2[0]))


def _dense(a_per_row):
    cfg = EMTConfig(mode="analog",
                    quant=QuantConfig(a_per_row=a_per_row))
    specs = dense_specs(16, 8, cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    return cfg, params


def test_emt_dense_per_row_output_is_cotenant_independent():
    cfg, params = _dense(a_per_row=True)
    rng = np.random.default_rng(0)
    x1 = rng.normal(size=(1, 16)).astype(np.float32)
    other_a = rng.normal(size=(1, 16)).astype(np.float32)
    other_b = 50.0 * rng.normal(size=(1, 16)).astype(np.float32)
    ya, _ = emt_dense(params, jnp.asarray(np.vstack([x1, other_a])), cfg,
                      tag="t", seed=3)
    yb, _ = emt_dense(params, jnp.asarray(np.vstack([x1, other_b])), cfg,
                      tag="t", seed=3)
    np.testing.assert_array_equal(np.asarray(ya[0]), np.asarray(yb[0]))
    # control: the per-tensor scale sees the loud co-tenant and shifts row 0
    cfg_t, params_t = _dense(a_per_row=False)
    za, _ = emt_dense(params_t, jnp.asarray(np.vstack([x1, other_a])), cfg_t,
                      tag="t", seed=3)
    zb, _ = emt_dense(params_t, jnp.asarray(np.vstack([x1, other_b])), cfg_t,
                      tag="t", seed=3)
    assert not np.array_equal(np.asarray(za[0]), np.asarray(zb[0]))


# ---------------------------------------------------------------------------
# serving regression: analog mode, mismatched admission schedules
# ---------------------------------------------------------------------------
def _analog_cfg(a_per_row):
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=4)
    if a_per_row:
        cfg = cfg.replace(emt=cfg.emt.replace(
            quant=dataclasses.replace(cfg.emt.quant, a_per_row=True)))
    return cfg


def _mismatch_runs(cfg):
    """Tokens from a block-starved paged engine (admissions delayed ->
    occupancy differs) vs each request served alone."""
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    # prompt seed chosen so the per-tensor negative control below actually
    # exhibits the occupancy coupling under the chunked-prefill admission
    # schedule (the PR 2 seed stopped flipping tokens once prompts moved to
    # exact positions)
    rng = np.random.default_rng(2)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, int(L))
                       .astype(np.int32), max_new=4, seed=i)
            for i, L in enumerate([5, 6, 4, 5])]
    tight = ServingEngine(cfg, params, batch_size=4, max_len=16, seed=7,
                          fresh_noise=False, paged=True, block_size=4,
                          num_blocks=6, num_ring_blocks=8)
    for r in reqs:
        tight.submit(r)
    got = {r.rid: r.tokens for r in tight.drain()}
    solo = ServingEngine(cfg, params, batch_size=1, max_len=16, seed=7,
                         fresh_noise=False)
    alone = {}
    for rid in sorted(got):
        solo.submit(GenRequest(prompt=reqs[rid].prompt,
                               max_new=reqs[rid].max_new, seed=reqs[rid].seed))
        (res,) = solo.drain()
        alone[rid] = res.tokens
    return got, alone


@pytest.mark.slow
def test_analog_identity_under_mismatched_schedules_with_per_row_scale():
    got, alone = _mismatch_runs(_analog_cfg(a_per_row=True))
    for rid in alone:
        np.testing.assert_array_equal(
            got[rid], alone[rid],
            err_msg=f"per-row DAC scale: request {rid} still "
                    f"occupancy-sensitive under mismatched admission")


@pytest.mark.slow
def test_analog_per_tensor_scale_is_occupancy_sensitive():
    """Negative control: with the paper's per-tensor DAC scale the same
    mismatched schedule perturbs tokens — the subtlety is real, so the fix
    above is load-bearing (if this starts passing, re-examine both)."""
    got, alone = _mismatch_runs(_analog_cfg(a_per_row=False))
    assert any(not np.array_equal(got[rid], alone[rid]) for rid in alone)
