# NOTE: no --xla_force_host_platform_device_count here — smoke tests and benches
# must see the real single CPU device; only launch/dryrun.py forces 512
# (and tests/test_distributed.py spawns subprocesses that set it themselves).
import os

import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    # hypothesis profiles: `ci` (default) keeps property harnesses inside the
    # tier-1 wall-time budget; the nightly workflow passes
    # `--hypothesis-profile=nightly` (the hypothesis pytest plugin's flag) to
    # raise the example budget ~10x.  Inline @settings(...) in test files
    # inherit every field they don't pin from the active profile, so tests
    # must NOT hardcode max_examples unless they mean to opt out of nightly.
    from hypothesis import settings

    settings.register_profile("ci", max_examples=6, deadline=None)
    settings.register_profile("nightly", max_examples=75, deadline=None,
                              print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:          # hypothesis is optional outside CI
    pass


def pytest_collection_modifyitems(config, items):
    # tier-1 = the fast verify suite (scripts/run_tier1.sh): everything not
    # explicitly opted out with @pytest.mark.slow
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)
