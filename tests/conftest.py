# NOTE: no --xla_force_host_platform_device_count here — smoke tests and benches
# must see the real single CPU device; only launch/dryrun.py forces 512
# (and tests/test_distributed.py spawns subprocesses that set it themselves).
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
