# NOTE: no --xla_force_host_platform_device_count here — smoke tests and benches
# must see the real single CPU device; only launch/dryrun.py forces 512
# (and tests/test_distributed.py spawns subprocesses that set it themselves).
import os

import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_collection_modifyitems(config, items):
    # tier-1 = the fast verify suite (scripts/run_tier1.sh): everything not
    # explicitly opted out with @pytest.mark.slow
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)
