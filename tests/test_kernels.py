"""Pallas kernels vs pure-jnp oracles: shape/dtype/device sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device import DeviceModel, four_state_device
from repro.kernels import ops, ref

DEVICES = {"2state": DeviceModel(), "4state": four_state_device()}


def _rel_err(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 384, 250),
                                   (64, 512, 128), (33, 130, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("devname", ["2state", "4state"])
def test_emt_matmul_sweep(m, k, n, dtype, devname):
    dev = DEVICES[devname]
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    y_k = ops.emt_matmul(x, w, 4.0, device=dev, seed_static=3, plane=7,
                         interpret=True)
    y_r = ref.emt_matmul_ref(x.reshape(-1, k), w, 4.0, device=dev, seed=3,
                             plane=7)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert _rel_err(y_k, y_r.reshape(y_k.shape)) < tol


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (100, 200, 60)])
@pytest.mark.parametrize("bits", [3, 7])
@pytest.mark.parametrize("devname", ["2state", "4state"])
def test_emt_bitserial_sweep(m, k, n, bits, devname):
    dev = DEVICES[devname]
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    qmax = 2 ** bits - 1
    xq = jnp.round(jnp.clip(x * 20, -qmax, qmax))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    y_k = ops.emt_bitserial_matmul(xq, w, 4.0, device=dev, bits=bits, seed=5,
                                   base_plane=11, interpret=True)
    y_r = ref.emt_bitserial_ref(xq, w, 4.0, device=dev, bits=bits, seed=5,
                                base_plane=11)
    assert _rel_err(y_k, y_r) < 1e-4


def test_kernel_3d_leading_dims():
    dev = DeviceModel()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    y = ops.emt_matmul(x, w, 4.0, device=dev, seed_static=0, interpret=True)
    assert y.shape == (2, 16, 64)
    y_r = ref.emt_matmul_ref(x.reshape(-1, 128), w, 4.0, device=dev, seed=0)
    assert _rel_err(y, y_r.reshape(y.shape)) < 1e-4


def test_noise_tiling_invariance():
    """Same result regardless of block decomposition (global-coordinate hash)."""
    dev = DeviceModel()
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    from repro.kernels.emt_matmul import emt_matmul_pallas
    y1 = emt_matmul_pallas(x, w, 4.0, device=dev, seed=9, bm=128, bn=128,
                           bk=128, interpret=True)
    y2 = emt_matmul_pallas(x, w, 4.0, device=dev, seed=9, bm=256, bn=256,
                           bk=256, interpret=True)
    assert _rel_err(y1, y2) < 1e-5


def test_bitserial_matches_analog_statistics():
    """Kernel-level check of Eq. 18: bit-serial output closer to the ideal."""
    dev = DeviceModel()
    xq = jnp.full((64, 128), 127.0)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    ideal = xq @ w
    errs_a, errs_b = [], []
    for s in range(8):
        ya = ops.emt_matmul(xq, w, 1.0, device=dev, seed_static=s,
                            interpret=True)
        yb = ops.emt_bitserial_matmul(xq, w, 1.0, device=dev, bits=7, seed=s,
                                      interpret=True)
        errs_a.append(float(jnp.std(ya - ideal)))
        errs_b.append(float(jnp.std(yb - ideal)))
    assert np.mean(errs_b) < np.mean(errs_a)
