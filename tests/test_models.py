"""Model-block correctness: attention variants, rope, masks, chunked==full."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emt_linear import IDEAL
from repro.models import common
from repro.models.attention import _gqa_core
from repro.models.config import ModelConfig
from repro.models.context import Ctx

CTX = Ctx()


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=64, head_dim=16,
                dtype=jnp.float32, emt=IDEAL)
    base.update(kw)
    return ModelConfig(**base)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = common.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    qb = jnp.broadcast_to(q, (1, 8, 1, 16))
    yq = common.apply_rope(qb, pos[:1])
    d1 = float(jnp.sum(yq[0, 2] * yq[0, 4]))
    d2 = float(jnp.sum(yq[0, 3] * yq[0, 5]))
    assert abs(d1 - d2) < 1e-4


def test_mrope_equals_rope_for_text():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    p3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    y1 = common.apply_rope(x, pos)
    y2 = common.apply_mrope(x, p3, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = common.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(np.asarray(common.softcap(x, 0.0)),
                               np.asarray(x))


def test_causal_and_window_masks():
    pos = jnp.arange(6)[None]
    m = common.causal_mask(pos, pos)[0, 0]
    assert float(m[2, 3]) < -1e29 and float(m[3, 2]) == 0.0
    mw = common.causal_mask(pos, pos, window=2)[0, 0]
    assert float(mw[4, 2]) < -1e29          # too far back
    assert float(mw[4, 3]) == 0.0


def test_chunked_attention_matches_full():
    cfg_full = _cfg(attn_chunk=0)
    cfg_chunk = _cfg(attn_chunk=4)
    B, Sq, H, hd = 2, 12, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, 2, hd))
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    mask = common.causal_mask(pos, pos)
    y_full = _gqa_core(q, k, v, mask, cfg_full, CTX)
    y_chunk = _gqa_core(q, k, v, mask, cfg_chunk, CTX)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chunk),
                               rtol=1e-5, atol=1e-5)
    # with softcap too
    cfg_full = _cfg(attn_chunk=0, attn_softcap=20.0)
    cfg_chunk = _cfg(attn_chunk=4, attn_softcap=20.0)
    y_full = _gqa_core(q, k, v, mask, cfg_full, CTX)
    y_chunk = _gqa_core(q, k, v, mask, cfg_chunk, CTX)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chunk),
                               rtol=1e-5, atol=1e-5)


def test_gqa_equals_mha_when_kv_repeated():
    """GQA with KV=H and duplicated heads == plain MHA math."""
    cfg = _cfg(num_kv_heads=4)
    B, S, H, hd = 1, 6, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask = common.causal_mask(pos, pos)
    y = _gqa_core(q, k, v, mask, cfg, CTX).reshape(B, S, H, hd)
    # manual per-head attention
    for h in range(H):
        s = (q[:, :, h] @ k[:, :, h].transpose(0, 2, 1)) / np.sqrt(hd)
        s = s + mask[:, 0]
        p = jax.nn.softmax(s, -1)
        ref = p @ v[:, :, h]
        np.testing.assert_allclose(np.asarray(y[:, :, h]), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
