"""Multi-device correctness (subprocess with 8 forced host devices).

Checks: sharded train step == unsharded reference; decode on a sharded cache;
elastic checkpoint restore across meshes; compressed pod psum correctness.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.models.context import Ctx
from repro.nn.param import init_params, param_shardings, abstract_params
from repro.parallel.sharding import RULES, batch_shardings, cache_shardings
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state, \
    make_state_shardings
from repro.serve.engine import make_decode_step

out = {}
mesh = make_mesh(2, 4)
rules = RULES["train_fsdp_tp"]
cfg = get_config("gemma2-9b", emt_mode="analog", smoke=True)
cfg = cfg.replace(dtype=jnp.float32, num_layers=2)
tcfg = TrainConfig(lam=1e-7, opt=OptimizerConfig(name="adamw"))

# --- sharded vs single-device train step -------------------------------
step_sh, opt = make_train_step(cfg, tcfg, mesh, rules)
step_ref, _ = make_train_step(cfg, tcfg, None, None)
state = init_state(cfg, opt, jax.random.PRNGKey(0))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8)
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

with mesh:
    sh, astate = make_state_shardings(cfg, opt, mesh, rules)
    state_sh = jax.device_put(state, sh)
    bsh = batch_shardings(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch), mesh, rules)
    batch_put = jax.device_put(batch, bsh)
    new_sh, m_sh = jax.jit(step_sh, in_shardings=(sh, bsh),
                           out_shardings=(sh, None))(state_sh, batch_put)
new_ref, m_ref = jax.jit(step_ref)(state, batch)
out["loss_sharded"] = float(m_sh["loss"])
out["loss_ref"] = float(m_ref["loss"])
pdiff = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(new_sh["params"]),
                            jax.tree.leaves(new_ref["params"])))
out["param_maxdiff"] = pdiff

# --- decode on sharded cache -------------------------------------------
srules = RULES["serve_2d"]
with mesh:
    psh = param_shardings(lm.specs(cfg), mesh, srules)
    params_put = jax.device_put(new_ref["params"], psh)
    cache = lm.init_cache(cfg, 8, 32)
    csh = cache_shardings(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache), mesh, srules)
    cache_put = jax.device_put(cache, csh)
    dstep = jax.jit(make_decode_step(cfg, mesh, srules),
                    in_shardings=(psh, csh, None, None, None),
                    out_shardings=(None, csh, None))
    toks = jnp.zeros((8,), jnp.int32)
    logits, cache_put, e = dstep(params_put, cache_put, toks,
                                 jnp.int32(0), jnp.uint32(0))
ref_logits, _, _ = lm.decode_step(new_ref["params"], cache, toks, 0, cfg,
                                  Ctx(seed=jnp.uint32(0)))
out["decode_maxdiff"] = float(jnp.max(jnp.abs(logits - ref_logits)))

# --- elastic checkpoint restore ----------------------------------------
from repro.ckpt.checkpoint import CheckpointManager
import tempfile
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(1, new_sh)                      # saved from the sharded mesh
    restored, _ = mgr.restore(1, new_ref)    # restored to single device
    rdiff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(restored["params"]),
                                jax.tree.leaves(new_sh["params"])))
    out["ckpt_reshard_maxdiff"] = rdiff

# --- compressed psum: error feedback bounds the error -------------------
from repro.parallel.collectives import _quantize_int8
x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
q, s = _quantize_int8(x)
err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - x))
out["int8_quant_err"] = float(err)
out["int8_scale"] = float(s)

print(json.dumps(out))
"""


@pytest.mark.slow
def test_distributed_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # sharded step reproduces the single-device step bitwise-ish
    assert abs(out["loss_sharded"] - out["loss_ref"]) < 1e-4
    assert out["param_maxdiff"] < 2e-4
    assert out["decode_maxdiff"] < 2e-3
    assert out["ckpt_reshard_maxdiff"] < 1e-6
    assert out["int8_quant_err"] <= out["int8_scale"] * 0.51
