"""Declarative serving specs + scenario matrix: round-trip, validation,
expansion, frontier reduction, and a 2-cell executor smoke.

The spec family (repro.serve.spec) is the single config surface every
serving driver builds through; these tests pin the API redesign's contract:
dict/JSON round-trip with hard unknown-key rejection, invalid combinations
rejected at spec time (not deep inside engine construction), deterministic
matrix expansion, and the executor emitting conserved, frontier-reducible
cell metrics.
"""
import json

import pytest

from repro.analysis.frontier import (dominates, frontier_report,
                                     pareto_front)
from repro.serve.spec import (MatrixSpec, ScenarioSpec, ServeSpec,
                              PAGED_ATTN_IMPLS)


# ---------------------------------------------------------------- ServeSpec

def test_serve_spec_round_trip():
    spec = ServeSpec(arch="gemma3-1b", mode="analog", all_global=True,
                     a_per_row=True, batch_size=2, max_len=32, paged=True,
                     block_size=8, prefix_cache=True, frozen_noise=True,
                     model_overrides={"num_layers": 2})
    d = spec.to_dict()
    assert json.loads(json.dumps(d)) == d          # JSON-safe
    assert ServeSpec.from_dict(d) == spec
    assert spec.replace(batch_size=4).batch_size == 4
    assert spec.emt_label == "analog"
    assert spec.replace(device="pcm").emt_label == "pcm"


def test_serve_spec_unknown_key_rejected():
    with pytest.raises(ValueError, match="unknown ServeSpec keys"):
        ServeSpec.from_dict({"arch": "gemma3-1b", "nope": 1})


def test_serve_spec_impl_list_matches_kernels():
    from repro.kernels.ops import PAGED_ATTN_IMPLS as KERNEL_IMPLS
    assert tuple(KERNEL_IMPLS) == PAGED_ATTN_IMPLS


@pytest.mark.parametrize("kw", [
    dict(mode="quantum"),
    dict(paged_attn_impl="cuda"),
    dict(placement="mixed", device="pcm"),
    dict(prefix_cache=True),                       # needs paged
    dict(batch_size=3, shards=2),
    dict(draft_placement="sram_digital", temperature=0.7),
    dict(draft_placement="sram_digital", shards=2, batch_size=4),
    dict(draft_placement="sram_digital", paged=True, prefix_cache=True),
    dict(top_p=0.0),
    dict(deadline_s=0.0),
    dict(energy_budget_uj=-1.0),
])
def test_serve_spec_invalid_combinations(kw):
    with pytest.raises(ValueError):
        ServeSpec(**kw)


def test_prefix_cache_on_ring_stack_rejected():
    # gemma3-1b has sliding-window ring layers: prefix caching must be
    # rejected at config resolution unless the stack is coerced all-global
    spec = ServeSpec(arch="gemma3-1b", smoke=True, paged=True,
                     prefix_cache=True)
    with pytest.raises(ValueError, match="all-global"):
        spec.build_config()
    cfg = spec.replace(all_global=True).build_config()
    assert cfg.sliding_window == 0 and "local" not in cfg.blocks()


def test_build_config_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown arch"):
        ServeSpec(arch="gpt-17").build_config()
    with pytest.raises(ValueError, match="unknown placement"):
        ServeSpec(placement="everything-on-pcm").build_config()
    with pytest.raises(ValueError, match="unknown device"):
        ServeSpec(device="memristor-9000").build_config()


# ------------------------------------------------------------- ScenarioSpec

def test_scenario_spec_round_trip_and_coords():
    cell = ScenarioSpec(name="c", serve=ServeSpec(batch_size=2),
                        arrival="stagger", stagger=2, n_requests=4,
                        prompt_lo=16, prompt_hi=16, shared_prefix_ratio=0.5,
                        max_new=4, coords=(("kv", "paged"), ("shared", "0.5")))
    d = cell.to_dict()
    assert json.loads(json.dumps(d)) == d
    assert ScenarioSpec.from_dict(d) == cell
    assert cell.header_len == 8
    assert cell.coord("kv") == "paged"
    assert cell.group_key(drop_axes=("kv",)) == (("shared", "0.5"),)
    with pytest.raises(ValueError, match="unknown ScenarioSpec keys"):
        ScenarioSpec.from_dict({"n_requests": 4, "arrivals": "poisson"})


@pytest.mark.parametrize("kw", [
    dict(arrival="burst"),
    dict(arrival="poisson", rate_rps=0.0),
    dict(arrival="stagger", stagger=0),
    dict(prompt_lo=8, prompt_hi=4),
    dict(shared_prefix_ratio=1.0),
    dict(n_requests=0),
])
def test_scenario_spec_invalid(kw):
    with pytest.raises(ValueError):
        ScenarioSpec(**kw)


# --------------------------------------------------------------- MatrixSpec

def _toggle(label, **set_):
    return {"label": label,
            "set": {k.replace("__", "."): v for k, v in set_.items()}}


def test_matrix_expansion_counts_and_names():
    base = ScenarioSpec(name="grid", serve=ServeSpec())
    extra = ScenarioSpec(name="poisson", arrival="poisson", rate_rps=4.0)
    m = MatrixSpec(
        name="m", base=base,
        axes={"shared_prefix_ratio": (0.0, 0.5),
              "kv": (_toggle("contig", serve__paged=False),
                     _toggle("paged", serve__paged=True),
                     _toggle("prefix", serve__paged=True,
                             serve__prefix_cache=True))},
        identity_axes=("kv",), extra_cells=(extra,))
    assert m.n_cells == 2 * 3 + 1
    cells = m.expand()
    assert len(cells) == 7
    assert len({c.name for c in cells}) == 7
    grid = [c for c in cells if c.coords]
    assert all(c.name.startswith("grid/") for c in grid)
    # the dotted-path axis landed in the scenario, the toggle in the serve
    pc = next(c for c in grid if c.coord("kv") == "prefix"
              and c.coord("shared_prefix_ratio") == "0.5")
    assert pc.shared_prefix_ratio == 0.5 and pc.serve.prefix_cache
    # identity groups: same non-identity coords, one per kv value
    groups = {}
    for c in grid:
        groups.setdefault(c.group_key(m.identity_axes), []).append(c)
    assert len(groups) == 2 and all(len(v) == 3 for v in groups.values())


def test_matrix_round_trip_and_validation():
    m = MatrixSpec(name="m", base=ScenarioSpec(),
                   axes={"max_new": (4, 8)}, identity_axes=())
    assert MatrixSpec.from_dict(json.loads(json.dumps(m.to_dict()))) == m
    with pytest.raises(ValueError, match="identity axis"):
        MatrixSpec(axes={"max_new": (4,)}, identity_axes=("kv",))
    with pytest.raises(ValueError, match="unknown field"):
        MatrixSpec(axes={"max_old": (4, 8)}).expand()
    # invalid axis value fails at expansion (cells are validated specs)
    with pytest.raises(ValueError, match="arrival"):
        MatrixSpec(axes={"arrival": ("lockstep", "burst")}).expand()


# ----------------------------------------------------------------- frontier

def test_pareto_front_basics():
    assert dominates((2.0, 1.0), (1.0, 1.0))
    assert not dominates((2.0, 0.5), (1.0, 1.0))
    pts = [(1.0, 1.0), (2.0, 0.5), (0.5, 2.0), (0.4, 0.4)]
    assert sorted(pareto_front(pts)) == [0, 1, 2]
    # duplicates both survive; missing metrics never enter the front
    assert sorted(pareto_front([(1.0, 1.0), (1.0, 1.0)])) == [0, 1]
    rep = frontier_report([
        {"name": "a", "emt_label": "analog", "decode_tok_per_s": 10.0,
         "uj_per_token": 1.0, "accuracy_proxy": 0.5},
        {"name": "b", "emt_label": "analog", "decode_tok_per_s": 5.0,
         "uj_per_token": 2.0, "accuracy_proxy": 0.5},
        {"name": "c", "emt_label": "analog", "decode_tok_per_s": None,
         "uj_per_token": 0.1, "accuracy_proxy": 0.9},
    ])
    assert rep["groups"]["analog"]["pareto"] == ["a", "c"]
    assert rep["pareto_names"] == ["a", "c"]
    assert "b" in rep["groups"]["analog"]["dominated"]


# ------------------------------------------------------- executor (2 cells)

def test_two_cell_executor_smoke():
    """Contiguous vs paged on the same tiny workload: both cells conserve
    energy, the identity axis holds, and the frontier is non-empty."""
    from benchmarks.matrix import run_matrix

    serve = ServeSpec(arch="gemma3-1b", mode="analog", smoke=True,
                      all_global=True, a_per_row=True, frozen_noise=True,
                      batch_size=2, paged_attn_impl="ref",
                      model_overrides={"num_layers": 2})
    base = ScenarioSpec(name="tiny", serve=serve, arrival="lockstep",
                        n_requests=2, prompt_lo=8, prompt_hi=8, max_new=2,
                        workload_seed=3)
    m = MatrixSpec(
        name="tiny-matrix", base=base,
        axes={"kv": (_toggle("contiguous", serve__paged=False),
                     _toggle("paged", serve__paged=True,
                             serve__block_size=8))},
        identity_axes=("kv",))
    section = run_matrix(m, with_proxy=False, verbose=False)
    assert len(section["cells"]) == 2
    for cell in section["cells"]:
        assert cell["energy_conserved"] is True
        assert cell["token_identity"] is True
        assert cell["tokens"] == 2 * 2
        assert cell["uj_per_token"] > 0
    assert all(g["identical"] for g in section["identity"].values())
    assert section["frontier"]["pareto_names"]    # non-empty Pareto set
    # the section is JSON-serializable as stored in BENCH_serve.json
    json.dumps(section)
