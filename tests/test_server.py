"""Streaming serving front-end: admission validation, token streaming,
cancellation/timeout, backpressure, and the drain() forward-progress guard.

Two layers of coverage:

* **engine-level, deterministic** — the ``on_token`` hook fires at sample
  time (a co-tenant's first token is observable strictly before an earlier
  request retires), ``cancel()`` works from queue and slot, ``validate()``
  raises hard ``ValueError``s (never bare asserts — they vanish under
  ``python -O``), and a stuck engine fails fast out of ``drain()`` instead
  of spinning.
* **server-level, threaded** — :class:`repro.serve.server.StreamingServer`
  round-trips: streamed tokens equal the final result, first tokens arrive
  while co-tenants are still in flight, deadline timeouts and bounded-queue
  rejections surface as ``done_reason="timeout"`` / ``RejectedError``, and
  per-request + idle == total energy conservation holds with partials.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest
from repro.serve.scheduler import RejectedError
from repro.serve.server import StreamingServer


def _cfg(num_layers=2):
    # all-global attention keeps the global block pool the admission gate
    # (the stall test leaks from it) and the stack small; "ref" paged attn
    # keeps the CPU runner off the interpret-mode kernel path
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    return cfg.replace(dtype=jnp.float32, num_layers=num_layers,
                       layer_pattern=("attn",), sliding_window=0,
                       paged_attn_impl="ref")


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    # one shared paged engine: jitted closures are per-instance, so reusing
    # it keeps this module off the compile path (tests drain it back to idle)
    eng = ServingEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                        fresh_noise=False, paged=True, block_size=8)
    return cfg, params, eng


def _reset(eng):
    assert not eng.scheduler.busy, "previous test left the engine busy"
    eng.total_energy_pj = 0.0
    eng.idle_energy_pj = 0.0
    eng.on_token = None
    return eng


def _mk(cfg, rng, n, **kw):
    return GenRequest(prompt=rng.integers(0, cfg.vocab_size, n)
                      .astype(np.int32), **kw)


# -- validation (satellite: hard errors, not asserts) -------------------------

def test_validate_raises_valueerror_not_assert(setup):
    cfg, params, eng = setup
    _reset(eng)
    rng = np.random.default_rng(0)
    ok = _mk(cfg, rng, 6, max_new=4)
    bad = [
        GenRequest(prompt=np.zeros(0, np.int32)),                  # empty
        _mk(cfg, rng, eng.max_len + 1),                            # too long
        GenRequest(prompt=ok.prompt, max_new=0),
        GenRequest(prompt=ok.prompt, temperature=-0.5),
        GenRequest(prompt=ok.prompt, top_p=-0.1),
        GenRequest(prompt=ok.prompt, top_k=-1),
    ]
    for req in bad:
        with pytest.raises(ValueError):
            eng.submit(req)
    assert eng.scheduler.pending == 0, "rejected request reached the queue"
    # a request that cannot fit even an empty pool is refused up front
    # (FIFO admission would otherwise head-block forever)
    tiny = ServingEngine(cfg, params, batch_size=1, max_len=32, seed=7,
                         fresh_noise=False, paged=True, block_size=8,
                         num_blocks=2)
    with pytest.raises(ValueError):
        tiny.validate(_mk(cfg, rng, 8, max_new=24))


def test_engine_fifo_backpressure(setup):
    cfg, params, eng = setup
    rng = np.random.default_rng(1)
    bounded = ServingEngine(cfg, params, batch_size=1, max_len=32, seed=7,
                            fresh_noise=False, paged=True, block_size=8,
                            max_pending=1)
    bounded.submit(_mk(cfg, rng, 4, max_new=2))
    with pytest.raises(RejectedError):
        bounded.submit(_mk(cfg, rng, 4, max_new=2))


# -- legacy bucketed prefill sizing (enc-dec regression) ----------------------

def test_legacy_bucket_clamp_encdec():
    """Enc-dec (legacy one-shot prefill) near capacity: a prompt whose pow2
    bucket exceeds ``max_len`` must prefill at *exact* length — bit-identical
    to the canonical unpadded prefill+decode path, never a cache overrun —
    and a prompt longer than ``max_len`` is a ``ValueError`` at submit."""
    from repro.models.context import Ctx
    from repro.serve.engine import prefill_bucket

    cfg = get_config("seamless-m4t-medium", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    max_len, max_new = 14, 2
    assert prefill_bucket(len(prompt)) > max_len   # the clamp must engage

    # canonical reference at exact length: no pow2 left-padding, so real
    # token positions start at 0 — the layout the clamped engine must match
    batch = {"tokens": jnp.asarray(prompt[None, :]),
             "enc_embeds": jnp.zeros((1, 13, cfg.d_model), jnp.float32)}
    ctx = Ctx(seed=jnp.uint32(3))
    cache, logits, _ = lm.prefill(params, batch, cfg, ctx,
                                  lm.init_cache(cfg, 1, max_len))
    want, pos = [int(jnp.argmax(logits[0]))], 13
    for _ in range(max_new - 1):
        logits, cache, _ = lm.decode_step(
            params, cache, jnp.asarray([want[-1]], jnp.int32), pos, cfg, ctx)
        want.append(int(jnp.argmax(logits[0])))
        pos += 1

    eng = ServingEngine(cfg, params, batch_size=1, max_len=max_len,
                        seed=3, fresh_noise=False)
    assert not eng.chunked, "enc-dec must take the legacy prefill path"
    assert eng._bucket_len(13) == 13               # clamped to exact length
    eng.submit(GenRequest(prompt=prompt, max_new=max_new))
    (res,) = eng.drain()
    np.testing.assert_array_equal(res.tokens, np.asarray(want, np.int32))
    with pytest.raises(ValueError):
        eng.submit(_mk(cfg, rng, 15, max_new=1))   # longer than max_len


# -- streaming (engine-level, deterministic) ----------------------------------

def test_on_token_streams_before_cotenant_retires(setup):
    """The acceptance property, without threads: a later request's first
    token is emitted via ``on_token`` strictly before the first request
    retires, and the streamed sequence equals each final result exactly."""
    cfg, params, eng = setup
    _reset(eng)
    rng = np.random.default_rng(3)
    emitted = {}                       # rid -> [(step, token), ...]
    eng.on_token = lambda rid, tok: emitted.setdefault(rid, []).append(
        (eng._steps, tok))

    rid0 = eng.submit(_mk(cfg, rng, 6, max_new=10, seed=1))
    rid1 = eng.submit(_mk(cfg, rng, 4, max_new=6, seed=2))
    results = {}
    while eng.scheduler.busy:
        for res in eng.step():
            results[res.rid] = (res, eng._steps)
    eng.on_token = None

    first_tok_step_r1 = emitted[rid1][0][0]
    retire_step_r0 = results[rid0][1]
    assert first_tok_step_r1 < retire_step_r0, \
        "co-tenant's first token must stream before the earlier request " \
        f"retires (r1 first @ step {first_tok_step_r1}, " \
        f"r0 retired @ step {retire_step_r0})"
    for rid in (rid0, rid1):
        np.testing.assert_array_equal(
            np.asarray([t for _, t in emitted[rid]], np.int32),
            results[rid][0].tokens,
            err_msg=f"streamed tokens diverge from final result (rid {rid})")


def test_engine_cancel_queued_and_mid_flight(setup):
    cfg, params, eng = setup
    _reset(eng)
    rng = np.random.default_rng(4)
    rid0 = eng.submit(_mk(cfg, rng, 6, max_new=16, seed=1))
    rid1 = eng.submit(_mk(cfg, rng, 6, max_new=4, seed=2))
    rid2 = eng.submit(_mk(cfg, rng, 6, max_new=4, seed=3))   # queued (batch 2)

    # queued: removed without ever occupying a slot
    res2 = eng.cancel(rid2)
    assert res2.done_reason == "cancelled" and len(res2.tokens) == 0
    assert res2.energy_pj == 0.0 and res2.steps == 0

    results = [res2]
    while eng.scheduler.slot_of(rid0) is None or not any(
            s.generated for i, s in eng.scheduler.active_slots()
            if s.rid == rid0):
        results += eng.step()
    sid = eng.scheduler.slot_of(rid0)
    n_at_cancel = len(eng.scheduler.slots[sid].generated)
    res0 = eng.cancel(rid0)                                  # mid-decode
    assert res0.done_reason == "cancelled"
    assert len(res0.tokens) == n_at_cancel > 0
    assert res0.energy_pj > 0, "partial energy must ride out on the result"
    assert eng.cancel(rid0) is None, "double-cancel must be a no-op"
    results += [res0] + eng.drain()

    assert {r.rid for r in results} == {rid0, rid1, rid2}
    eng.kv.check()
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


# -- drain() forward-progress guard -------------------------------------------

def test_drain_raises_on_stuck_engine(setup):
    """A pending request that can never be admitted (its block budget is held
    by a leaked owner) must fail drain() with the stuck state, not spin."""
    cfg, params, _ = setup
    eng = ServingEngine(cfg, params, batch_size=1, max_len=32, seed=7,
                        fresh_noise=False, paged=True, block_size=8,
                        num_blocks=4)
    rng = np.random.default_rng(5)
    leaked = eng.kv.pool_g.alloc(owner=999, blocks=3)
    assert leaked is not None
    eng.submit(_mk(cfg, rng, 8, max_new=16))     # fits the pool, not the rest
    with pytest.raises(RuntimeError, match="no progress"):
        eng.drain(stall_limit=4)


# -- server-level (threaded) --------------------------------------------------

def test_server_streams_cotenants_and_conserves_energy(setup):
    cfg, params, eng = setup
    _reset(eng)
    rng = np.random.default_rng(6)
    with StreamingServer(eng, max_pending=4) as srv:
        h0 = srv.submit(_mk(cfg, rng, 8, max_new=12, seed=1))
        h1 = srv.submit(_mk(cfg, rng, 5, max_new=8, seed=2))
        t1 = h1.next_token(timeout=120)
        assert t1 is not None
        assert not h0.done, \
            "h1's first token must stream while h0 is still in flight"
        streamed1 = [t1] + list(h1.tokens(timeout=120))
        r0, r1 = h0.result(timeout=120), h1.result(timeout=120)
    assert r0.done_reason == "max_new" and r1.done_reason == "max_new"
    np.testing.assert_array_equal(np.asarray(streamed1, np.int32), r1.tokens)
    assert h0.ttft_s is not None and h0.ttft_s > 0
    assert len(h0.itl_s) == len(r0.tokens) - 1
    assert all(d >= 0 for d in h0.itl_s)
    assert srv.stats["completed"] == 2 and srv.stats["submitted"] == 2
    total = r0.energy_pj + r1.energy_pj + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


def test_server_cancel_timeout_and_backpressure(setup):
    cfg, params, eng = setup
    _reset(eng)
    rng = np.random.default_rng(7)
    with StreamingServer(eng, max_pending=1) as srv:
        # cancel mid-stream: partial result, energy still billed
        hc = srv.submit(_mk(cfg, rng, 6, max_new=24, seed=1))
        got = []
        for tok in hc.tokens(timeout=120):
            got.append(tok)
            if len(got) == 2:
                hc.cancel()
        rc = hc.result(timeout=120)
        assert rc.done_reason == "cancelled"
        assert len(rc.tokens) >= 2 and rc.energy_pj > 0
        np.testing.assert_array_equal(rc.tokens[:2], np.asarray(got[:2]))

        # deadline: expires mid-flight -> done_reason="timeout"
        ht = srv.submit(_mk(cfg, rng, 6, max_new=24, seed=2),
                        deadline_s=0.05)
        rt = ht.result(timeout=120)
        assert rt.done_reason == "timeout"
        assert len(rt.tokens) < 24

        # backpressure: a burst into the 1-deep admission queue must shed
        # load (the driver can pump at most batch_size + 1 ahead of the
        # engine, and these arrive faster than any slot can retire)
        accepted, rejected = [], 0
        for i in range(8):
            try:
                accepted.append(srv.submit(_mk(cfg, rng, 6, max_new=16,
                                               seed=10 + i)))
            except RejectedError:
                rejected += 1
        assert rejected > 0, "bounded queue never rejected"
        assert accepted, "burst was rejected entirely"
        for h in accepted:
            h.result(timeout=120)
    assert srv.stats["cancelled"] == 1 and srv.stats["timeout"] == 1
    assert srv.stats["rejected"] == rejected
    eng.kv.check()
    assert not eng.scheduler.busy
