"""Energy-budget control plane: per-request SLA shedding + bucket admission.

docs/control_plane.md properties under test:

* a request with an `energy_budget_uj` is shed through the normal
  cancel/retire path once its billed energy crosses the budget —
  `done_reason="energy_budget"`, partial tokens ride out, and per-request
  (incl. the shed partial) + idle == total conservation holds;
* the overrun is bounded: the check is post-hoc, so the billed energy is
  >= the budget but the request never runs a full step past it;
* a generous budget never triggers (no false sheds);
* the engine-level uJ token bucket head-blocks *admission* while
  overdrawn (arrival order kept, nothing already admitted is shed) and the
  idle-engine exception prevents deadlock — the deferred request runs
  after the engine drains;
* the StreamingServer surfaces sheds end-to-end (`stats["energy_budget"]`),
  including on a SpeculativeEngine where the draft placement's energy
  counts against the same budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.placement import emt_for_corner
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.control import EnergyBudgetController
from repro.serve.engine import GenRequest, ServingEngine
from repro.serve.server import StreamingServer
from repro.serve.speculative import SpeculativeEngine


def _cfg():
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("attn",), sliding_window=0)
    tgt = emt_for_corner("pcm")
    tgt = tgt.replace(quant=dataclasses.replace(tgt.quant, a_per_row=True))
    return cfg.replace(emt=tgt)


def _req(cfg, seed=0, plen=8, max_new=12, **kw):
    rng = np.random.default_rng(seed)
    return GenRequest(prompt=rng.integers(0, cfg.vocab_size, plen)
                      .astype(np.int32), max_new=max_new, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                        fresh_noise=False)
    # reference run: how much one unconstrained request costs end to end
    free = eng.serve([_req(cfg)])[0]
    assert free.done_reason == "max_new" and free.energy_pj > 0
    return cfg, params, eng, free


def test_validate_rejects_nonpositive_budget(setup):
    cfg, _, eng, _ = setup
    for bad in (0.0, -1.0):
        with pytest.raises(ValueError, match="energy_budget_uj"):
            eng.validate(_req(cfg, energy_budget_uj=bad))
    with pytest.raises(ValueError, match="step_budget_uj"):
        EnergyBudgetController(step_budget_uj=0.0)


def test_budget_shed_partial_tokens_and_conservation(setup):
    cfg, _, eng, free = setup
    eng.controller = ctl = EnergyBudgetController()   # SLA shedding only
    try:
        snap = (eng.total_energy_pj, eng.idle_energy_pj)
        budget_uj = free.energy_pj * 1e-6 * 0.5
        res = eng.serve([_req(cfg, energy_budget_uj=budget_uj)])[0]
        assert res.done_reason == "energy_budget"
        assert 0 < len(res.tokens) < free.tokens.size
        assert ctl.shed == 1
        # post-hoc shed: crossed the budget, but by less than a full extra
        # serve (the overrun is one step's share)
        assert res.energy_pj * 1e-6 >= budget_uj
        assert res.energy_pj < free.energy_pj
        # conservation with the shed partial (scenario-delta form)
        d_total = eng.total_energy_pj - snap[0]
        d_idle = eng.idle_energy_pj - snap[1]
        assert np.isclose(res.energy_pj + d_idle, d_total, rtol=1e-6)
    finally:
        eng.controller = None


def test_generous_budget_never_sheds(setup):
    cfg, _, eng, free = setup
    eng.controller = ctl = EnergyBudgetController()
    try:
        res = eng.serve([_req(cfg, energy_budget_uj=free.energy_pj * 1e-5)])[0]
        assert res.done_reason == "max_new"
        np.testing.assert_array_equal(res.tokens, free.tokens)
        assert ctl.shed == 0
    finally:
        eng.controller = None


def test_bucket_defers_admission_until_drain(setup):
    cfg, _, eng, free = setup
    # per-step cost of the reference request; a bucket refilling at 5% of
    # that overdraws immediately and stays overdrawn while anything runs
    step_uj = free.energy_pj * 1e-6 / max(free.steps, 1)
    eng.controller = ctl = EnergyBudgetController(step_budget_uj=0.05 * step_uj)
    try:
        eng.submit(_req(cfg, seed=1))
        results = []
        for _ in range(3):                  # overdraw the (full) bucket
            results += eng.step()
        eng.submit(_req(cfg, seed=2))
        max_active = 0
        for _ in range(64):
            results += eng.step()
            max_active = max(max_active, eng.scheduler.num_active)
            if not eng.scheduler.busy:
                break
        assert not eng.scheduler.busy
        # the second request head-blocked until the first drained (then the
        # idle-engine exception admitted it) — never two slots at once
        assert max_active == 1
        assert ctl.deferred_steps > 0
        assert sorted(r.done_reason for r in results) == ["max_new"] * 2
        assert all(len(r.tokens) == 12 for r in results)
    finally:
        eng.controller = None


def test_streaming_server_sheds_on_speculative_engine():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(3))
    ctl = EnergyBudgetController()
    eng = SpeculativeEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                            fresh_noise=False, spec_k=3, controller=ctl)
    free = eng.serve([_req(cfg, seed=5)])[0]
    assert free.done_reason == "max_new"
    budget_uj = free.energy_pj * 1e-6 * 0.4
    with StreamingServer(eng, max_pending=4) as srv:
        h_shed = srv.submit(_req(cfg, seed=5, energy_budget_uj=budget_uj))
        h_ok = srv.submit(_req(cfg, seed=6))
        shed_res = h_shed.result(timeout=120)
        ok_res = h_ok.result(timeout=120)
    assert shed_res.done_reason == "energy_budget"
    assert 0 < len(shed_res.tokens) < free.tokens.size
    assert ok_res.done_reason == "max_new"
    assert srv.stats["energy_budget"] == 1
    assert srv.stats["completed"] == 1
    assert ctl.shed == 1
    # the two-placement ledger conserves across the whole engine lifetime,
    # shed partial included
    total = free.energy_pj + shed_res.energy_pj + ok_res.energy_pj
    assert np.isclose(total + eng.idle_energy_pj, eng.total_energy_pj,
                      rtol=1e-6)
    assert shed_res.draft_energy_pj > 0    # draft share counted against SLA
