"""Fault-tolerant loop: loss decreases, checkpoint-resume continues exactly."""
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state
from repro.train.loop import LoopConfig, train_loop


def _setup(tmp_path, steps):
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2)
    tcfg = TrainConfig(lam=1e-7, lr=2e-3, warmup=5, total_steps=steps,
                       opt=OptimizerConfig(name="adamw"))
    step_fn, opt = make_train_step(cfg, tcfg, None, None)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    lcfg = LoopConfig(total_steps=steps, ckpt_every=10,
                      ckpt_dir=str(tmp_path), log_every=5,
                      metrics_path=str(tmp_path / "m.jsonl"))
    return state, jitted, data, lcfg


def test_loss_decreases_and_resume(tmp_path):
    state, jitted, data, lcfg = _setup(tmp_path, steps=30)
    state, hist = train_loop(state, jitted, data.batch_at, lcfg,
                             log=lambda *a: None)
    assert hist[-1]["ce"] < hist[0]["ce"]          # learning happens
    assert int(jax.device_get(state["step"])) == 30
    assert os.path.exists(str(tmp_path / "m.jsonl"))

    # extend run: resumes from the saved step-30 checkpoint, not from scratch
    lcfg2 = LoopConfig(total_steps=40, ckpt_every=10, ckpt_dir=str(tmp_path),
                       log_every=5)
    msgs = []
    state2, _ = train_loop(init_state_like(state), jitted, data.batch_at,
                           lcfg2, log=msgs.append)
    assert any("resumed from step 30" in m for m in msgs)
    assert int(jax.device_get(state2["step"])) == 40


def init_state_like(state):
    return jax.tree.map(lambda x: jnp.zeros_like(x), state)


def test_straggler_hook_fires_on_slow_step(tmp_path):
    state, jitted, data, lcfg = _setup(tmp_path, steps=12)
    lcfg.straggler_factor = 0.0     # every step counts as a straggler
    hooks = []
    train_loop(state, jitted, data.batch_at, lcfg,
               straggler_hook=hooks.append, log=lambda *a: None)
    assert hooks, "watchdog should have fired with factor 0"
