"""Train-forward vs prefill+decode logits consistency (ideal mode, no noise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.emt_linear import IDEAL
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.nn.param import init_params

CTX = Ctx()


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=3, d_model=48,
                num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
                head_dim=12, dtype=jnp.float32, emt=IDEAL, remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("kw", [
    {},
    {"layer_pattern": ("local", "global"), "sliding_window": 4,
     "attn_softcap": 30.0, "final_softcap": 20.0},
    {"layer_pattern": ("mamba", "attn")},
    {"layer_pattern": ("mlstm", "slstm"), "d_ff": 0},
])
def test_prefill_decode_matches_full_forward(kw):
    cfg = _cfg(**kw)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    # full forward logits at every position (training path, labels unused)
    from repro.models import common, stack as stk
    x = common.embed(params["embed"], toks, cfg.embed_scale, cfg.d_model)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    masks = {"global": common.causal_mask(pos, pos),
             "local": common.causal_mask(pos, pos, cfg.sliding_window)}
    h, _, _ = stk.apply_stack(params["decoder"], x.astype(cfg.dtype), cfg,
                              cfg.blocks(), cfg.moe_layer_mask(), ctx=CTX,
                              tag="dec", positions=pos, mask=masks)
    h = common.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits_full, _ = lm._logits(params, h, cfg, CTX)

    # prefill on the first S-1 tokens, then decode token S-1
    cache = lm.init_cache(cfg, B, S + 1)
    cache, logits_prefill, _ = lm.prefill(
        params, {"tokens": toks[:, :S - 1]}, cfg, CTX, cache)
    np.testing.assert_allclose(np.asarray(logits_prefill),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=2e-3, atol=2e-3)
    logits_dec, cache, _ = lm.decode_step(params, cache, toks[:, S - 1], S - 1,
                                          cfg, CTX)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
