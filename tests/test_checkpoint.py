"""Checkpoint manager: roundtrip, integrity, GC, async, elastic-template restore."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.arange(3.0)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state(1.5)
    mgr.save(7, st, extra={"note": "x"})
    restored, meta = mgr.restore(7, _state())
    assert meta["step"] == 7 and meta["extra"]["note"] == "x"
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 1.5)
    assert int(restored["step"]) == 7


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.all_steps() == [3, 4]
    restored, meta = mgr.restore_latest(_state())
    assert meta["step"] == 4


def test_integrity_check(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state())
    path = os.path.join(str(tmp_path), "step_000000001", "state.npz")
    with open(path, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        mgr.restore(1, _state())


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, _state(2.0))
    mgr.wait()
    restored, _ = mgr.restore(5, _state())
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.0)


def test_missing_tensor_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        mgr.restore(1, {"a": jnp.zeros(2), "b": jnp.zeros(3)})


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.zeros(3)})
