"""Per-architecture smoke tests: reduced same-family config, one train step +
one decode step on CPU, asserting shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.models.context import Ctx
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, make_train_step, init_state


@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_smoke_train_and_decode(arch):
    cfg = get_config(arch, emt_mode="analog", smoke=True)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.input_kind == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)

    tcfg = TrainConfig(lam=1e-6, opt=OptimizerConfig(name="adamw"))
    step_fn, opt = make_train_step(cfg, tcfg, None, None)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    new_state, metrics = jax.jit(step_fn)(state, batch)

    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert float(metrics["energy_uj"]) > 0, arch       # EMT active
    assert int(new_state["step"]) == 1
    # params actually changed (global delta across all leaves)
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state["params"]),
                                jax.tree.leaves(new_state["params"])))
    assert delta > 0

    # one decode step against a prefim cache
    cache = lm.init_cache(cfg, B, S + 2)
    ctx = Ctx(seed=jnp.uint32(1))
    cache, logits, _ = lm.prefill(new_state["params"], batch, cfg, ctx, cache)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache, _ = lm.decode_step(new_state["params"], cache, tok, S,
                                       cfg, ctx)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch
