"""Counter-hash RNG: determinism, marginals, plane independence."""
import jax.numpy as jnp
import numpy as np

from repro.core import hashrng
from repro.core.device import DeviceModel, four_state_device


def test_deterministic_and_coordinate_stable():
    a = hashrng.tile_uniform_bits(7, 0, 0, (64, 64))
    b = hashrng.tile_uniform_bits(7, 0, 0, (64, 64))
    assert bool(jnp.all(a == b))
    # a shifted-origin tile reproduces the overlapping region exactly
    big = hashrng.tile_uniform_bits(7, 0, 0, (64, 64))
    sub = hashrng.tile_uniform_bits(7, 16, 32, (16, 16))
    assert bool(jnp.all(big[16:32, 32:48] == sub))


def test_seed_and_plane_change_stream():
    a = hashrng.tile_uniform_bits(1, 0, 0, (32, 32))
    b = hashrng.tile_uniform_bits(2, 0, 0, (32, 32))
    c = hashrng.tile_uniform_bits(1, 0, 0, (32, 32), plane=1)
    assert float(jnp.mean((a == b).astype(jnp.float32))) < 0.01
    assert float(jnp.mean((a == c).astype(jnp.float32))) < 0.01


def test_uniformity():
    bits = hashrng.tile_uniform_bits(3, 0, 0, (256, 256))
    u = np.asarray(bits).astype(np.float64) / 2**32
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.std() - np.sqrt(1 / 12)) < 0.01
    # bit balance on low bit
    assert abs(np.mean(np.asarray(bits) & 1) - 0.5) < 0.01


def test_state_probabilities_two_and_four():
    for dev in (DeviceModel(), four_state_device()):
        offs = hashrng.tile_state_offsets(11, 0, 0, (512, 512),
                                          dev.state_offsets, dev.state_probs)
        offs = np.asarray(offs)
        for target, p in zip(dev.state_offsets, dev.state_probs):
            frac = np.mean(np.isclose(offs, target, atol=1e-6))
            assert abs(frac - p) < 0.01, (target, frac, p)
        # empirical moments ~ (0, 1)
        assert abs(offs.mean()) < 0.01
        assert abs(offs.std() - 1.0) < 0.01
