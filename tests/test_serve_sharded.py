"""Data-parallel serving over the simulated mesh (n_shards > 1).

Host-side units run in-process (scheduler slot-to-shard assignment, per-shard
block pools, cross-shard prefix-miss accounting — pure Python, no devices
needed).  The end-to-end property harness runs in a subprocess with 4 forced
host devices: sharded decode must be token-identical to the single-device
baseline at temperature 0 (ideal and analog with the per-row DAC scale),
through staggered backfill admission, the contiguous and paged layouts, the
prefix cache, and cancel-mid-decode — and every run must conserve energy
including the per-shard ledger split.
"""
import json
import os
import subprocess
import sys

import numpy as np

from repro.serve.kv_pool import PagedKV
from repro.serve.scheduler import Scheduler, Slot


def _occupy(sch, slot_id, rid=0):
    sch.place(slot_id, Slot(rid=rid, req=None, pos=0, last_token=0))


# -- scheduler: slot-to-shard assignment ------------------------------------

def test_pick_shard_least_occupied():
    sch = Scheduler(batch_size=8, n_shards=4)        # shard_size = 2
    for slot in (0, 4, 5, 6):                        # occupancy [1, 0, 2, 1]
        _occupy(sch, slot, rid=slot)
    assert sch.pick_shard(4, 4) == 1                 # emptiest shard wins
    for slot in (2, 3):                              # occupancy [1, 2, 2, 1]
        _occupy(sch, slot, rid=slot)
    assert sch.pick_shard(4, 4) == 0                 # tie -> lowest shard id
    for slot in (1, 7):                              # all full
        _occupy(sch, slot, rid=slot)
    assert sch.pick_shard(4, 4) is None
    assert not sch.can_admit(4, 4)
    sch.retire(5)                                    # frees shard 2 only
    assert sch.pick_shard(4, 4) == 2                 # backfill is shard-local
    assert sch.free_slot(shard=2) == 5
    assert sch.free_slot(shard=0) is None


def test_pick_shard_skips_exhausted_block_budget():
    kv = PagedKV(batch_size=4, max_len=32, block_size=8, num_blocks=8,
                 n_shards=2)                         # 4 blocks per shard
    sch = Scheduler(batch_size=4, kv=kv, n_shards=2)
    # prompt 16 + 17 new = 32 positions -> 2 alloc + 2 reserved = the whole
    # shard pool; both shards empty, tie -> shard 0
    assert sch.pick_shard(16, 17) == 0
    assert kv.admit(0, 16, 17)
    _occupy(sch, 0)
    # shard 0 has a free slot (1) but zero block headroom -> shard 1
    assert sch.pick_shard(16, 17) == 1
    assert kv.admit(2, 16, 17)
    _occupy(sch, 2, rid=1)
    # free slots remain on both shards, but neither pool can host anything
    assert sch.pick_shard(1, 1) is None
    kv.check()


def test_shard_of_partition():
    sch = Scheduler(batch_size=8, n_shards=4)
    assert [sch.shard_of(i) for i in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]


# -- kv pool: per-shard pools, shard-local ids ------------------------------

def test_tables_hold_shard_local_ids():
    kv = PagedKV(batch_size=4, max_len=32, block_size=8, num_blocks=8,
                 n_shards=2)
    assert kv.admit(0, 12, 8) and kv.admit(2, 12, 8)
    npb = kv.pools_g[0].num_blocks
    for slot in (0, 2):
        ids = kv.table_g[slot][kv.table_g[slot] >= 0]
        assert len(ids) == 2
        assert all(0 <= b < npb for b in ids), "table id not shard-local"
        assert set(map(int, ids)) == set(
            kv.pools_g[kv.shard_of(slot)].owned(slot))
    # both slots legitimately hold the *same local ids* in different pools
    assert sorted(kv.table_g[0].tolist()) == sorted(kv.table_g[2].tolist())
    kv.check()
    kv.ensure(0, 16)                                 # decode append: local id
    assert 0 <= kv.table_g[0, 2] < npb
    g, _ = kv.release(0)
    assert all(0 <= b < npb for b in g)
    kv.check()


def test_cross_shard_prefix_miss_counter():
    kv = PagedKV(batch_size=4, max_len=32, block_size=4, num_blocks=16,
                 n_shards=2)
    prompt = np.arange(9, dtype=np.int32)            # 2 full blocks + tail
    res = kv.admit_prefix(0, prompt, max_new=4)      # slot 0 -> shard 0
    assert res is not None and res["cached_len"] == 0
    kv.register_filled(0, 8)                         # register both blocks
    kv.release(0)                                    # park them cached-free
    # same prompt admitted on shard 0 hits the chain...
    res = kv.admit_prefix(1, prompt, max_new=4)
    assert res is not None and res["cached_len"] == 8
    assert kv.prefix_hits == 2
    assert kv.cross_shard_prefix_misses == 0
    # ...but on shard 1 the registry is empty: the would-have-hit walk is
    # counted as a cross-shard miss and nothing is shared
    res = kv.admit_prefix(2, prompt, max_new=4)
    assert res is not None and res["cached_len"] == 0
    assert kv.cross_shard_prefix_misses == 1
    assert kv.prefix_hits == 2
    kv.check()


# -- end-to-end: sharded == single-device (subprocess, 4 forced devices) ----

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import json
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest

assert jax.device_count() == 4

rng = np.random.default_rng(0)
N_REQ = 10


def build(mode, all_global=False):
    cfg = get_config("gemma3-1b", emt_mode=mode, smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    if all_global:
        # prefix cache needs an all-global attention stack (no ring layers)
        cfg = cfg.replace(num_layers=2, layer_pattern=("attn",),
                          sliding_window=0, paged_attn_impl="ref")
    if mode == "analog":
        # per-row DAC scale: activation quantization must not couple
        # co-tenant rows, or shard placement would perturb tokens
        cfg = cfg.replace(emt=cfg.emt.replace(
            quant=dataclasses.replace(cfg.emt.quant, a_per_row=True)))
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 20))).astype(np.int32)
               for _ in range(N_REQ)]
    return cfg, params, prompts


def run(cfg, params, prompts, n_shards, batch, paged=True, prefix=False,
        cancel_rid=None):
    eng = ServingEngine(cfg, params, batch_size=batch, max_len=64, seed=7,
                        fresh_noise=False, paged=paged, block_size=8,
                        n_shards=n_shards, prefix_cache=prefix)
    for i, p in enumerate(prompts):      # N_REQ > batch: staggered backfill
        eng.submit(GenRequest(prompt=p, max_new=8, seed=i))
    results, steps = [], 0
    while eng.scheduler.busy:
        results += eng.step()
        steps += 1
        if cancel_rid is not None and steps == 3:
            r = eng.cancel(cancel_rid)
            if r is not None:
                results.append(r)
        assert steps < 500
    toks = {r.rid: list(map(int, r.tokens)) for r in results}
    billed = sum(r.energy_pj for r in results)
    # per-request + idle == total, and the per-shard split re-sums exactly
    assert np.isclose(billed + eng.idle_energy_pj, eng.total_energy_pj,
                      rtol=1e-6)
    assert np.isclose(eng.shard_energy_pj.sum(), eng.total_energy_pj,
                      rtol=1e-9)
    assert np.isclose(eng.shard_idle_energy_pj.sum(), eng.idle_energy_pj,
                      rtol=1e-9)
    for name, tot in eng.corner_energy_pj.items():
        assert np.isclose(eng.shard_corner_energy_pj[name].sum(), tot,
                          rtol=1e-9), name
    if paged:
        eng.kv.check()
    return toks, eng


out = {}
for mode in ("ideal", "analog"):
    cfg, params, prompts = build(mode)
    base, _ = run(cfg, params, prompts, 1, 4)
    runs = [(4, 8, dict())] if mode == "ideal" else \
        [(2, 8, dict()), (4, 8, dict()), (4, 8, dict(paged=False))]
    for n, b, kw in runs:
        toks, eng = run(cfg, params, prompts, n, b, **kw)
        key = f"{mode}_n{n}B{b}" + ("_unpaged" if kw.get("paged") is False
                                    else "")
        out[key] = bool(toks == base)
        if n == 4 and not kw:
            occ = eng.shard_occupancy
            out[f"{mode}_balance"] = float(occ.min()) / float(occ.max())

# prefix cache + cancel-mid-decode on an all-global stack (ring K/V cannot
# be shared, so the prefix cache refuses sliding-window configs)
cfg, params, prompts = build("analog", all_global=True)
base, _ = run(cfg, params, prompts, 1, 4)
toks, eng = run(cfg, params, prompts, 4, 8, prefix=True)
out["analog_prefix"] = bool(toks == base)
toks, eng = run(cfg, params, prompts, 4, 8, prefix=True, cancel_rid=3)
out["analog_cancel_others_identical"] = bool(
    all(v == base[k] for k, v in toks.items() if k != 3))
out["analog_cancel_is_prefix"] = bool(
    toks[3] == base[3][:len(toks[3])])

print(json.dumps(out))
"""


def test_sharded_token_identity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for key, val in out.items():
        if key.endswith("_balance"):
            assert val >= 0.5, (key, val, out)
        else:
            assert val is True, (key, out)
