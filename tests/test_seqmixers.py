"""Sequence mixers: parallel/chunked forms vs step-by-step recurrence.

The strongest invariant in the repo: full-sequence mixing and token-by-token
decoding with carried state must agree (mamba, mLSTM, sLSTM) — this is what makes
long_500k decode correct.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emt_linear import IDEAL
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models import mamba as mam
from repro.models import xlstm as xl
from repro.nn.param import init_params

CTX = Ctx()


def _cfg(**kw):
    base = dict(name="t", family="ssm", num_layers=1, d_model=32, num_heads=2,
                num_kv_heads=2, d_ff=0, vocab_size=64, head_dim=16,
                dtype=jnp.float32, emt=IDEAL)
    base.update(kw)
    return ModelConfig(**base)


def test_selective_scan_matches_lax_scan():
    B, S, DI, N = 2, 16, 8, 4
    dA = jax.random.uniform(jax.random.PRNGKey(0), (B, S, DI, N),
                            minval=0.1, maxval=0.95)
    dBx = jax.random.normal(jax.random.PRNGKey(1), (B, S, DI, N))
    h_all, h_last = mam._selective_scan(dA, dBx, chunk=5)

    def step(h, t):
        h = dA[:, t] * h + dBx[:, t]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros((B, DI, N)), jnp.arange(S))
    ref = jnp.moveaxis(hs, 0, 1)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_mamba_prefill_decode_consistency():
    cfg = _cfg()
    params = init_params(mam.mamba_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_full, _, st_full = mam.mamba(params, x, cfg, ctx=CTX, tag="m")
    # token-by-token with carried state
    state = {"h": jnp.zeros((B, cfg.d_inner, cfg.ssm_state)),
             "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner))}
    ys = []
    for t in range(S):
        y, _, state = mam.mamba(params, x[:, t:t + 1], cfg, ctx=CTX, tag="m",
                                state=state)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full["h"]), np.asarray(state["h"]),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_prefill_decode_consistency():
    cfg = _cfg()
    params = init_params(xl.mlstm_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_full, _, st = xl.mlstm(params, x, cfg, ctx=CTX, tag="x")
    H, DI = cfg.num_heads, 2 * cfg.d_model
    hd = DI // H
    state = {"C": jnp.zeros((B, H, hd, hd)), "n": jnp.zeros((B, H, hd)),
             "conv": jnp.zeros((B, 3, DI))}
    ys = []
    for t in range(S):
        y, _, state = xl.mlstm(params, x[:, t:t + 1], cfg, ctx=CTX, tag="x",
                               state=state)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st["C"]), np.asarray(state["C"]),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunking_invariance():
    cfg = _cfg()
    params = init_params(xl.mlstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model)) * 0.5
    import repro.models.xlstm as xmod
    old = xmod.MLSTM_CHUNK
    try:
        xmod.MLSTM_CHUNK = 4
        y4, _, _ = xl.mlstm(params, x, cfg, ctx=CTX, tag="x")
        xmod.MLSTM_CHUNK = 12
        y12, _, _ = xl.mlstm(params, x, cfg, ctx=CTX, tag="x")
    finally:
        xmod.MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y12), rtol=1e-4,
                               atol=1e-4)


def test_slstm_prefill_decode_consistency():
    cfg = _cfg()
    params = init_params(xl.slstm_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_full, _, st = xl.slstm(params, x, cfg, ctx=CTX, tag="s")
    state = {"c": jnp.zeros((B, cfg.d_model)), "n": jnp.zeros((B, cfg.d_model))}
    ys = []
    for t in range(S):
        y, _, state = xl.slstm(params, x[:, t:t + 1], cfg, ctx=CTX, tag="s",
                               state=state)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)


def test_slstm_recurrent_variant_runs():
    cfg = _cfg(slstm_recurrent=True)
    params = init_params(xl.slstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    y, _, _ = xl.slstm(params, x, cfg, ctx=CTX, tag="s")
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
