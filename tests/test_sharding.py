"""Sharding rules: dim-aware pspec construction (single-device mesh; the
multi-device behaviour is covered by tests/test_distributed.py)."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.nn.param import logical_to_pspec, ParamSpec, param_shardings
from repro.parallel.sharding import RULES


class FakeMesh:
    """Duck-typed mesh: just axis names + sizes (pspec math is pure)."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.zeros(tuple(sizes.values()))


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
R = RULES["train_fsdp_tp"]


def test_basic_mapping():
    spec = logical_to_pspec(("embed", "mlp"), R, MESH, (4096, 14336))
    assert spec == P("data", "model")


def test_non_divisible_axis_dropped():
    # 1-kv-head cache dim cannot shard 16 ways
    spec = logical_to_pspec(("batch", "seq", "kv_heads", None), R, MESH,
                            (128, 4096, 1, 128))
    assert spec[2] is None
    # but 8 kv heads can't shard 16-way either
    spec = logical_to_pspec(("batch", "seq", "kv_heads", None), R, MESH,
                            (128, 4096, 8, 128))
    assert spec[2] is None


def test_axis_used_once():
    # expert takes model first; mlp then falls back to replication
    spec = logical_to_pspec(("expert", "embed", "mlp"), R, MESH,
                            (16, 4096, 8192))
    assert spec == P("model", "data", None)


def test_multi_axis_batch_multipod():
    spec = logical_to_pspec(("batch", "seq"), R, MESH3, (256, 4096))
    assert spec[0] == ("pod", "data")


def test_multi_axis_partial_when_not_divisible():
    # batch 16 divides pod(2)*? -> pod*data=32 doesn't divide 16; picks pod only
    spec = logical_to_pspec(("batch",), R, MESH3, (16,))
    assert spec == P(("pod",)) or spec == P("pod")


def test_param_shardings_tree():
    mesh = FakeMesh({"data": 2, "model": 2})
    specs = {"w": ParamSpec((64, 128), axes=("embed", "mlp")),
             "b": ParamSpec((128,), axes=("mlp",))}
    # NamedSharding requires a real Mesh; use a 1-device mesh and check specs
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    real = Mesh(devs, ("data", "model"))
    sh = param_shardings(specs, real, R)
    assert sh["w"].spec == P("data", "model")
    assert sh["b"].spec == P("model")


def test_serve_rules_shard_cache_seq():
    spec = logical_to_pspec(("batch", "seq", "kv_heads", None),
                            RULES["serve_2d"], MESH, (128, 32768, 8, 128))
    assert spec[1] == "model"       # seq over model (the 1.4TB-cache fix)
    assert spec[2] is None
