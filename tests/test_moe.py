"""MoE: routing mass conservation, capacity behaviour, single-expert equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emt_linear import IDEAL
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.models import moe
from repro.nn.param import init_params

CTX = Ctx()


def _cfg(**kw):
    base = dict(name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
                num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
                dtype=jnp.float32, emt=IDEAL, num_experts=4,
                experts_per_token=2, moe_d_ff=64)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_forward_finite_and_shaped():
    cfg = _cfg()
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_ffn(params, x, cfg, ctx=CTX, tag="moe")
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux["aux_loss"]) > 0


def test_single_expert_topk1_equals_dense_mlp():
    """E=1, k=1, capacity >= tokens: MoE must reduce to its expert MLP."""
    cfg = _cfg(num_experts=1, experts_per_token=1, capacity_factor=64.0)
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    y, _ = moe.moe_ffn(params, x, cfg, ctx=CTX, tag="moe")
    # dense reference with the same weights
    act = jax.nn.silu
    h = act(x @ params["wg"][0]) * (x @ params["wu"][0])
    ref = h @ params["wd"][0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_capacity_drops_tokens():
    """Tiny capacity factor: outputs shrink toward zero (dropped tokens)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    big = _cfg(capacity_factor=8.0)
    small = big.replace(capacity_factor=0.05)
    params = init_params(moe.moe_specs(big), jax.random.PRNGKey(0))
    y_big, _ = moe.moe_ffn(params, x, big, ctx=CTX, tag="m")
    y_small, _ = moe.moe_ffn(params, x, small, ctx=CTX, tag="m")
    norm_big = float(jnp.linalg.norm(y_big))
    norm_small = float(jnp.linalg.norm(y_small))
    assert norm_small < norm_big * 0.7


def test_router_gradients_flow():
    cfg = _cfg()
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))

    def loss(p):
        y, aux = moe.moe_ffn(p, x, cfg, ctx=CTX, tag="m")
        return jnp.mean(y ** 2) + aux["aux_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["router"]["w"])) > 0
    assert float(jnp.linalg.norm(g["wg"])) > 0


def test_emt_moe_energy_accounting():
    from repro.configs.common import emt_preset
    cfg = _cfg(emt=emt_preset("analog"))
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    y, aux = moe.moe_ffn(params, x, cfg, ctx=CTX, tag="m")
    assert float(aux["energy_pj"]) > 0
    assert aux["cells"] == 3 * 4 * 32 * 64
    assert bool(jnp.all(jnp.isfinite(y)))
