"""HLO text parser: shapes, group sizes, operand-byte conventions."""
from repro.analysis.hlo import analyze_collectives, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[16]") == 32
    assert shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("f32[]") == 4


HLO = """
HloModule test
ENTRY %main {
  %ar = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[4096]{0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%z), channel_id=3, replica_groups=[2,4]<=[8], to_apply=%add
  %aa = bf16[512]{0} all-to-all(%w), channel_id=4, replica_groups=[1,8]<=[8]
  %cp = f32[100]{0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
}
"""


def test_collective_operand_conventions():
    res = analyze_collectives(HLO)
    by = res["bytes_by_type"]
    assert by["all-reduce"] == 1024 * 4                    # operand == output
    assert by["all-gather"] == 4096 * 4 / 4                # output / group
    assert by["reduce-scatter"] == 256 * 4 * 4             # output * group
    assert by["all-to-all"] == 512 * 2
    assert by["collective-permute"] == 100 * 4
    assert res["count_by_type"]["all-reduce"] == 1
    assert res["num_while"] == 0
    assert len(res["top_collectives"]) == 5


def test_async_pairs_counted_once():
    hlo = """
  %s = f32[1000]{0} all-reduce-start(%x), replica_groups=[1,8]<=[8]
  %d = f32[1000]{0} all-reduce-done(%s)
"""
    res = analyze_collectives(hlo)
    assert res["count_by_type"]["all-reduce"] == 1
    assert res["bytes_by_type"]["all-reduce"] == 4000


def test_while_detected():
    hlo = "%w = (s32[], f32[4]) while(%t), condition=%c, body=%b"
    assert analyze_collectives(hlo)["num_while"] == 1
