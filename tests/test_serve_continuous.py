"""Continuous-batching serving engine: mid-decode admission equivalence,
per-request energy accounting, and per-slot seeded sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest
from repro.serve.sampling import sample_tokens


def _cfg(num_layers=6):
    # gemma3 smoke: 5 local (ring, window 8) + 1 global layer — exercises both
    # vectorized decode cache paths
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    return cfg.replace(dtype=jnp.float32, num_layers=num_layers)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _solo_tokens(cfg, params, req, *, max_len=24, seed=7):
    """Run one request alone on a fresh single-slot engine."""
    eng = ServingEngine(cfg, params, batch_size=1, max_len=max_len, seed=seed,
                        fresh_noise=False)
    eng.submit(req)
    (res,) = eng.drain()
    return res.tokens


def test_midstream_admission_matches_solo_and_energy_splits(setup):
    """A request admitted mid-decode (other slots at different positions)
    generates exactly the tokens it generates alone at temperature 0, and the
    per-request energies sum to the engine's total."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                   max_new=6),
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 3).astype(np.int32),
                   max_new=8),
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                   max_new=5),
    ]
    # frozen noise: generation is a pure function of the request, so solo and
    # staggered runs see identical EMT fluctuation (analog mode, energy > 0)
    eng = ServingEngine(cfg, params, batch_size=2, max_len=24, seed=7,
                        fresh_noise=False)
    results = []
    eng.submit(reqs[0])
    results += eng.step()            # admits r0, decodes
    results += eng.step()
    eng.submit(reqs[1])              # r1 backfills while r0 is mid-decode
    results += eng.step()
    positions = {s.rid: s.pos for _, s in eng.scheduler.active_slots()}
    assert len(positions) == 2 and len(set(positions.values())) == 2, \
        f"slots should be mid-decode at different positions: {positions}"
    eng.submit(reqs[2])              # queued until a slot retires
    results += eng.drain()

    assert sorted(r.rid for r in results) == [0, 1, 2]
    by_rid = {r.rid: r for r in results}
    for rid, req in enumerate(reqs):
        solo = _solo_tokens(cfg, params, req)
        np.testing.assert_array_equal(by_rid[rid].tokens, solo)
        assert len(by_rid[rid].tokens) == req.max_new
        assert by_rid[rid].energy_pj > 0
        assert by_rid[rid].prefill_energy_pj > 0

    # conservation: per-request energy + idle-slot waste == engine total
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


def test_generate_backcompat_and_eos(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, 4)
                       .astype(np.int32), max_new=4) for _ in range(2)]
    eng = ServingEngine(cfg, params, batch_size=2, max_len=16, seed=3)
    outs1, e1 = eng.generate(reqs)
    outs2, e2 = eng.generate(reqs)      # noise clock resets: bit-identical
    for a, b in zip(outs1, outs2):
        np.testing.assert_array_equal(a, b)
    assert e1 > 0 and abs(e1 - e2) / e1 < 1e-6

    # eos stops early: use the first generated token as the eos id
    eos = int(outs1[0][0])
    eng2 = ServingEngine(cfg, params, batch_size=2, max_len=16, seed=3)
    res = None
    eng2.submit(GenRequest(prompt=reqs[0].prompt, max_new=4, eos_id=eos))
    for r in eng2.drain():
        res = r
    assert res.done_reason == "eos" and len(res.tokens) == 1


def test_temperature_sampling_deterministic_per_seed_and_varies(setup):
    """temperature > 0 is honored: same request seed -> same tokens; different
    seeds -> different streams. Deterministic regardless of slot placement."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)

    def run(seed, batch_size=1):
        eng = ServingEngine(cfg, params, batch_size=batch_size, max_len=16,
                            seed=11, fresh_noise=False)
        eng.submit(GenRequest(prompt=prompt, max_new=8, temperature=1.5,
                              seed=seed))
        (res,) = eng.drain()
        return res.tokens

    a1, a2 = run(seed=123), run(seed=123)
    np.testing.assert_array_equal(a1, a2)
    b = run(seed=456)
    assert not np.array_equal(a1, b), "different sampling seeds must diverge"
    # slot-placement independence: same request in a wider batch
    np.testing.assert_array_equal(a1, run(seed=123, batch_size=2))
    # and it actually sampled something non-greedy somewhere
    eng = ServingEngine(cfg, params, batch_size=1, max_len=16, seed=11,
                        fresh_noise=False)
    eng.submit(GenRequest(prompt=prompt, max_new=8, temperature=0.0))
    (res,) = eng.drain()
    assert not np.array_equal(res.tokens, a1)


def test_energy_conservation_paged(setup):
    """Per-request + idle == total still holds under paged serving when
    requests hold different block counts (mixed prompt buckets / decode
    budgets), and every request is billed a positive energy."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    # bucket 4 vs bucket 8 prompts, short vs long decode: 2 vs 4 global blocks
    reqs = [
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 3).astype(np.int32),
                   max_new=3),
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   max_new=8),
        GenRequest(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                   max_new=5),
    ]
    eng = ServingEngine(cfg, params, batch_size=2, max_len=24, seed=7,
                        fresh_noise=False, paged=True, block_size=4)
    results = eng.serve(reqs, stagger=2)
    assert sorted(r.rid for r in results) == [0, 1, 2]
    counts = {r.rid: len(r.tokens) for r in results}
    assert counts == {0: 3, 1: 8, 2: 5}
    for r in results:
        assert r.energy_pj > 0 and r.prefill_energy_pj > 0
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)
    # all blocks back and zeroed once everything retired
    eng.kv.check()
    assert eng.kv.pool_g.num_free == eng.kv.pool_g.num_blocks


def test_retired_slot_region_zeroed(setup):
    """Regression for the latent backfill bug: a retired slot's contiguous
    cache region must be zeroed at retirement, not merely overwritten by the
    next admission's full-region scatter (partial/paged inserts would
    otherwise read the previous request's stale K/V)."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    eng = ServingEngine(cfg, params, batch_size=2, max_len=16, seed=3,
                        fresh_noise=False)
    eng.submit(GenRequest(prompt=rng.integers(0, cfg.vocab_size, 5)
                          .astype(np.int32), max_new=3))
    eng.drain()
    for name, blk in eng.cache.items():
        for key, arr in blk.items():
            assert float(jnp.abs(arr[0]).max()) == 0.0, \
                f"stale data left in slot 0 of {name}/{key} after retirement"


def test_sample_tokens_unit():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    seeds = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    pos = jnp.zeros(4, jnp.int32)
    greedy = np.argmax(np.asarray(logits), -1)

    def run(t, k=0, p=1.0):
        return np.asarray(sample_tokens(
            logits, jnp.full(4, t, jnp.float32), jnp.full(4, k, jnp.int32),
            jnp.full(4, p, jnp.float32), seeds, pos))

    np.testing.assert_array_equal(run(0.0), greedy)          # temp 0 = argmax
    np.testing.assert_array_equal(run(5.0, k=1), greedy)     # top-k=1 = argmax
    np.testing.assert_array_equal(run(5.0, p=1e-6), greedy)  # tiny nucleus
    np.testing.assert_array_equal(run(2.0), run(2.0))        # deterministic
    # position advances the stream
    moved = np.asarray(sample_tokens(
        logits, jnp.full(4, 5.0, jnp.float32), jnp.zeros(4, jnp.int32),
        jnp.ones(4, jnp.float32), seeds, pos + 1))
    assert not np.array_equal(run(5.0), moved)
