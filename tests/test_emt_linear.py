"""EMT dense layer: modes, accounting, technique-B gradients, energy ordering."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import EMTConfig, emt_dense, dense_specs, QuantConfig
from repro.core.emt_linear import add_aux, new_aux
from repro.core.regularizer import rho_from_raw, rho_init_raw
from repro.nn.param import init_params

X = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))


def _layer(mode, **kw):
    cfg = EMTConfig(mode=mode, **kw)
    specs = dense_specs(64, 32, cfg, bias=True)
    return cfg, init_params(specs, jax.random.PRNGKey(0))


@pytest.mark.parametrize("mode", ["ideal", "analog", "bitserial"])
def test_modes_finite_and_shaped(mode):
    cfg, params = _layer(mode)
    y, aux = emt_dense(params, X, cfg, tag="t", seed=3)
    assert y.shape == (4, 16, 32)
    assert bool(jnp.all(jnp.isfinite(y)))
    if mode != "ideal":
        assert aux["cells"] == 64 * 32
        assert float(aux["energy_pj"]) > 0


def test_analog_converges_to_ideal_at_high_rho():
    cfg, params = _layer("analog", rho_init=1e9)
    y, _ = emt_dense(params, X, cfg, tag="t", seed=3)
    y_ideal = X @ params["w"] + params["b"]
    rel = float(jnp.linalg.norm(y - y_ideal) / jnp.linalg.norm(y_ideal))
    assert rel < 0.03      # residual is 8-bit quantization only


def test_noise_decreases_with_rho():
    errs = []
    for rho in (0.5, 4.0, 64.0):
        cfg, params = _layer("analog", rho_init=rho,
                             quant=QuantConfig(enabled=False))
        y, _ = emt_dense(params, X, cfg, tag="t", seed=3)
        errs.append(float(jnp.linalg.norm(y - (X @ params["w"] + params["b"]))))
    assert errs[0] > errs[1] > errs[2]


def test_bitserial_energy_below_analog():
    cfg_a, params = _layer("analog")
    cfg_b = EMTConfig(mode="bitserial")
    _, aux_a = emt_dense(params, X, cfg_a, tag="t", seed=3)
    _, aux_b = emt_dense(params, X, cfg_b, tag="t", seed=3)
    assert float(aux_b["energy_pj"]) < float(aux_a["energy_pj"])   # Eq. 20


def test_reg_term_gradients_reduce_rho_and_weights():
    """Fig. 7: descending lam*reg shrinks both rho and sum|w|."""
    cfg, params = _layer("analog")

    def reg_loss(p):
        _, aux = emt_dense(p, X, cfg, tag="t", seed=3)
        return aux["reg"]

    g = jax.grad(reg_loss)(params)
    assert float(g["rho_raw"]) > 0                   # pushes rho down
    # weight gradient has the sign of w (|w| subgradient)
    mask = jnp.abs(params["w"]) > 1e-3
    agree = jnp.mean((jnp.sign(g["w"]) == jnp.sign(params["w"]))[mask])
    assert float(agree) > 0.99


def test_energy_accounting_off():
    cfg = EMTConfig(mode="analog", energy_accounting="off")
    specs = dense_specs(64, 32, cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    y, aux = emt_dense(params, X, cfg, tag="t", seed=1)
    assert float(aux["energy_pj"]) == 0.0
    assert aux["cells"] == 64 * 32


def test_rho_raw_roundtrip():
    for rho in (0.01, 1.0, 4.0, 100.0):
        assert abs(float(rho_from_raw(jnp.float32(rho_init_raw(rho)))) - rho) \
            < 1e-3 * rho + 1e-5


def test_aux_merge():
    a, b = new_aux(), new_aux()
    a["cells"], b["cells"] = 3, 4
    assert add_aux(a, b)["cells"] == 7
