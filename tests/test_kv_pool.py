"""Block allocator + paged-KV host state: no double allocation, free-list
conservation under churn, and consistent refusal on out-of-blocks admission."""
import numpy as np
import pytest

from repro.serve.kv_pool import BlockPool, PagedKV


def test_alloc_unique_and_free_roundtrip():
    pool = BlockPool(num_blocks=8, block_size=4)
    a = pool.alloc(0, 3)
    b = pool.alloc(1, 3)
    assert a is not None and b is not None
    assert len(set(a) | set(b)) == 6, "blocks handed out twice"
    assert pool.num_free == 2
    pool.check()
    freed = pool.free(0)
    assert sorted(freed) == sorted(a)
    assert pool.num_free == 5
    pool.check()


def test_blocks_for_rounding():
    pool = BlockPool(num_blocks=8, block_size=4)
    assert [pool.blocks_for(n) for n in (0, 1, 4, 5, 8)] == [0, 1, 1, 2, 2]


def test_reservation_backs_append_and_counts_against_admission():
    pool = BlockPool(num_blocks=4, block_size=4)
    ids = pool.alloc(0, 1, reserve=2)
    assert ids is not None
    # 1 owned + 2 reserved: only 1 block of admission headroom left
    assert pool.num_free == 1
    assert pool.alloc(1, 2) is None, "reservation must not be admission headroom"
    b1 = pool.append(0)
    b2 = pool.append(0)
    assert len({ids[0], b1, b2}) == 3
    with pytest.raises(AssertionError):
        pool.append(0)                       # credits exhausted
    pool.check()
    assert sorted(pool.free(0)) == sorted([ids[0], b1, b2])
    assert pool.num_free == 4


def test_oom_refusal_leaves_pool_consistent():
    pool = BlockPool(num_blocks=4, block_size=4)
    ids = pool.alloc(0, 2)
    before = (pool.num_free, sorted(pool.owned(0)))
    assert pool.alloc(1, 2, reserve=1) is None     # needs 3, only 2 free
    assert (pool.num_free, sorted(pool.owned(0))) == before
    assert pool.owned(1) == []
    pool.check()
    more = pool.alloc(1, 2)                        # exact fit still works
    assert more is not None and not (set(more) & set(ids))
    pool.check()


def test_conservation_under_random_churn():
    rng = np.random.default_rng(0)
    pool = BlockPool(num_blocks=16, block_size=4)
    live = {}
    for t in range(300):
        if live and (rng.random() < 0.4 or len(live) == 8):
            owner = int(rng.choice(list(live)))
            pool.free(owner)
            del live[owner]
        else:
            owner = t + 1000
            n = int(rng.integers(1, 4))
            r = int(rng.integers(0, 3))
            ids = pool.alloc(owner, n, reserve=r)
            if ids is not None:
                live[owner] = True
                for _ in range(int(rng.integers(0, r + 1))):
                    pool.append(owner)
        pool.check()
    for owner in list(live):
        pool.free(owner)
    pool.check()
    assert pool.num_free == 16 and pool.num_owned == 0


def test_reuse_weighted_eviction_keeps_hot_prefix():
    """Regression for blind-LRU eviction: a hot shared-prefix block (many
    cache hits) must survive churn from cold single-use blocks even when it
    is the *oldest* release in the cached-free list — exactly the case where
    pure LRU rotated the shared system prompt out of the cache."""
    pool = BlockPool(num_blocks=6, block_size=4)
    toks = np.arange(4, dtype=np.int32)

    def park(owner, key):
        (bid,) = pool.alloc(owner, 1)
        assert pool.register(bid, key, None, toks)
        pool.free(owner)                     # registered -> cached-free
        return bid

    hot = park(0, b"hot")
    for i in range(3):                       # three prefix-cache hits
        pool.acquire(100 + i, hot)
        pool.free(100 + i)
    cold = [park(10 + i, b"c%d" % i) for i in range(3)]
    # hot parked first (oldest release), weight 3; colds parked after, weight 0
    assert pool.reuse_weight(hot) == 3.0
    pool.check()

    # 2 blanks remain; asking for 4 forces two evictions — the two coldest
    # (FIFO among the never-hit blocks), never the hot block
    assert pool.alloc(50, 4) is not None
    assert pool.lookup(b"hot") == hot
    assert pool.lookup(b"c0") is None and pool.lookup(b"c1") is None
    assert pool.lookup(b"c2") == cold[2]
    # survivors decay once per eviction: 3 * 0.9^2
    assert np.isclose(pool.reuse_weight(hot), 3.0 * 0.9**2)
    pool.check()

    # keep churning: hot outlives the last cold block too, and is evicted
    # only when it is the sole remaining candidate
    assert pool.alloc(51, 1) is not None
    assert pool.lookup(b"c2") is None and pool.lookup(b"hot") == hot
    assert pool.alloc(52, 1) is not None
    assert pool.lookup(b"hot") is None
    assert sorted(pool.pop_evicted()) == sorted(cold + [hot])
    pool.check()


def test_paged_kv_admit_tables_and_release():
    kv = PagedKV(batch_size=2, max_len=16, block_size=4, num_blocks=5,
                 ring_len=8, num_ring_blocks=4)
    assert kv.width_g == 4 and kv.width_l == 2
    # bucket 8 prompt + 4 new tokens -> positions 11 -> 2 alloc + 1 reserve
    assert kv.needs(8, 4) == (2, 1, 2)
    assert kv.admit(0, 8, 4)
    tg, tl = kv.gather_tables()
    assert (kv.table_g[0, :2] >= 0).all() and (kv.table_g[0, 2:] == -1).all()
    assert (tg[0, 2:] == kv.zero_block_g).all(), "unallocated -> zero block"
    assert (tl[0] != kv.zero_block_l).all(), "ring fully allocated at admission"
    # append-on-decode at the block boundary
    assert not kv.ensure(0, 7)                   # inside an allocated block
    assert kv.ensure(0, 8)                       # crosses into block 2
    assert kv.table_g[0, 2] >= 0
    rg, _ = kv.scatter_rows(0)
    assert (rg[3] == kv.zero_block_g + 1), "scatter sentinel is out of bounds"
    # second admission must refuse: it needs 2+1 g-blocks but owner 0 holds 3
    # of 5 (2 allocated + 1 appended), leaving only 2 free
    assert kv.can_admit(8, 4) is False
    kv.check()
    g, l = kv.release(0)
    assert len(g) == 3 and len(l) == 2
    assert (kv.table_g[0] == -1).all() and (kv.table_l[0] == -1).all()
    assert kv.can_admit(8, 4)
    kv.check()


def test_paged_kv_fits_vs_pool_capacity():
    kv = PagedKV(batch_size=1, max_len=32, block_size=4, num_blocks=4)
    assert kv.fits(8, 4)          # 3 blocks worst case
    assert not kv.fits(16, 8)     # ceil(23/4) = 6 > 4: would deadlock FIFO
