"""Cancellation on the paged + prefix-cached engine: block hygiene + energy.

Satellite harness for the streaming front-end PR: ``ServingEngine.cancel``
retires a slot early through exactly the same refcount/zero-on-retire path
as a natural retirement, so the properties the pool tests pin down must
survive cancellation too:

* **refcount conservation** — ``BlockPool.check()`` passes after cancelling
  mid-prefill and mid-decode: every block blank xor cached xor active, no
  leaks, reservations backed.
* **zero-on-retire** — with prefix caching off, a cancelled request's blocks
  are zeroed before they can be backfilled: stale K/V from an aborted
  request must never be gatherable.
* **prefix-cache survival** — cancelling a request that shares cached prefix
  blocks drops one reference; the cached chain stays resident and hit-able,
  and a later request still admits against it for free.
* **energy conservation with partials** — cancelled results keep the energy
  already billed; per-request (incl. partials) + idle == engine total.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest

BLOCK = 4


def _cfg():
    # all-global attention (prefix caching requires it), analog so cancelled
    # partials carry energy > 0; "ref" paged attention off the kernel path
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    return cfg.replace(dtype=jnp.float32, num_layers=2,
                       layer_pattern=("attn",), sliding_window=0,
                       paged_attn_impl="ref")


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))

    def engine(prefix_cache):
        return ServingEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                             fresh_noise=False, paged=True, block_size=BLOCK,
                             prefill_chunk=8, prefix_cache=prefix_cache)

    # per-instance jit closures: share one engine per variant across tests
    return cfg, {False: engine(False), True: engine(True)}


def _reset(eng):
    assert not eng.scheduler.busy, "previous test left the engine busy"
    eng.total_energy_pj = 0.0
    eng.idle_energy_pj = 0.0
    return eng


def _mk(cfg, rng, n, **kw):
    return GenRequest(prompt=rng.integers(0, cfg.vocab_size, n)
                      .astype(np.int32), **kw)


def _step_until(eng, results, pred, limit=64):
    for _ in range(limit):
        if pred():
            return
        results += eng.step()
    raise AssertionError("predicate never satisfied")


def _assert_all_blocks_zero(eng):
    for name, blk in eng.cache.items():
        for key, arr in blk.items():
            assert float(jnp.abs(arr).max()) == 0.0, \
                f"stale data left in {name}/{key} after cancel"


def test_cancel_mid_prefill(setup):
    """Cancel while the prompt is still streaming in: no tokens yet, but the
    chunk energy already spent is billed, the blocks go back, and nothing
    stale survives in the pool."""
    cfg, engines = setup
    eng = _reset(engines[False])
    rng = np.random.default_rng(0)
    free0 = eng.kv.pool_g.num_free

    rid = eng.submit(_mk(cfg, rng, 24, max_new=8, seed=1))  # 3 chunks of 8
    results = []
    results += eng.step()                                   # chunk 1 of 3
    sid = eng.scheduler.slot_of(rid)
    assert sid is not None and eng.scheduler.slots[sid].prefilling
    assert eng.kv.pool_g.num_free < free0

    res = eng.cancel(rid)
    assert res.done_reason == "cancelled"
    assert len(res.tokens) == 0, "mid-prefill cancel has no sampled tokens"
    assert res.energy_pj > 0, "partial prefill energy must be billed"
    results.append(res)

    eng.kv.check()
    assert eng.kv.pool_g.num_free == free0, "cancel leaked blocks"
    _assert_all_blocks_zero(eng)
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


def test_cancel_mid_decode_with_cotenant(setup):
    """Cancel one of two co-tenants mid-decode: the partial keeps its tokens
    and energy, the survivor is untouched, freed blocks are zeroed before a
    backfilled request can gather them, and conservation holds."""
    cfg, engines = setup
    eng = _reset(engines[False])
    rng = np.random.default_rng(1)

    rid0 = eng.submit(_mk(cfg, rng, 10, max_new=20, seed=1))
    rid1 = eng.submit(_mk(cfg, rng, 6, max_new=6, seed=2))
    results = []
    _step_until(eng, results, lambda: any(
        s.rid == rid0 and len(s.generated) >= 3
        for _, s in eng.scheduler.active_slots()))
    sid = eng.scheduler.slot_of(rid0)
    n_at_cancel = len(eng.scheduler.slots[sid].generated)

    res0 = eng.cancel(rid0)
    assert res0.done_reason == "cancelled"
    assert len(res0.tokens) == n_at_cancel >= 3
    assert res0.energy_pj > res0.prefill_energy_pj > 0
    eng.kv.check()

    # backfill into the freed blocks, then finish everything
    rid2 = eng.submit(_mk(cfg, rng, 8, max_new=4, seed=3))
    results += [res0] + eng.drain()
    by_rid = {r.rid: r for r in results}
    assert by_rid[rid1].done_reason == "max_new"
    assert len(by_rid[rid1].tokens) == 6, "cancel disturbed the co-tenant"
    assert by_rid[rid2].done_reason == "max_new"

    eng.kv.check()
    _assert_all_blocks_zero(eng)
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


def test_cancel_keeps_cached_prefix_hitable(setup):
    """A cancelled request only drops its own reference on shared prefix
    blocks: the cached chain survives and a later request with the same
    prefix still admits against it (pool hits, zero incremental prefill)."""
    cfg, engines = setup
    eng = _reset(engines[True])
    rng = np.random.default_rng(2)
    head = rng.integers(0, cfg.vocab_size, 2 * BLOCK).astype(np.int32)

    def with_head(tail_len, seed):
        tail = rng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)
        return GenRequest(prompt=np.concatenate([head, tail]), max_new=4,
                          seed=seed)

    # A registers the head chain, retires, blocks park cached-free
    eng.submit(with_head(4, seed=1))
    results = eng.drain()
    assert eng.kv.pool_g.num_cached > 0

    # B admits against the cached head, then is cancelled mid-decode
    hits0 = eng.kv.pool_g.hits
    cached_toks0 = eng.cached_prefix_tokens
    ridb = eng.submit(with_head(3, seed=2))
    _step_until(eng, results, lambda: any(
        s.rid == ridb and len(s.generated) >= 1
        for _, s in eng.scheduler.active_slots()))
    assert eng.kv.pool_g.hits > hits0, "B never hit the cached prefix"
    assert eng.cached_prefix_tokens > cached_toks0
    resb = eng.cancel(ridb)
    assert resb.done_reason == "cancelled" and len(resb.tokens) >= 1
    results.append(resb)
    eng.kv.check()
    assert eng.kv.pool_g.num_cached > 0, \
        "cancel evicted the shared prefix chain"

    # C still hits the same chain after the cancel
    hits1 = eng.kv.pool_g.hits
    cached_toks1 = eng.cached_prefix_tokens
    eng.submit(with_head(5, seed=3))
    results += eng.drain()
    assert eng.kv.pool_g.hits > hits1, "cancel broke prefix-cache hits"
    assert eng.cached_prefix_tokens > cached_toks1

    eng.kv.check()
    total = sum(r.energy_pj for r in results) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)


def test_cancel_timeout_reason_passthrough(setup):
    """cancel(reason="timeout") is the deadline path: same hygiene, distinct
    done_reason so clients can tell shed load from user cancellation."""
    cfg, engines = setup
    eng = _reset(engines[False])
    rng = np.random.default_rng(3)
    rid = eng.submit(_mk(cfg, rng, 6, max_new=16, seed=1))
    results = []
    _step_until(eng, results, lambda: any(
        s.rid == rid and len(s.generated) >= 1
        for _, s in eng.scheduler.active_slots()))
    res = eng.cancel(rid, reason="timeout")
    assert res.done_reason == "timeout" and len(res.tokens) >= 1
    eng.kv.check()
    _assert_all_blocks_zero(eng)
    total = sum(r.energy_pj for r in results + [res]) + eng.idle_energy_pj
    np.testing.assert_allclose(total, eng.total_energy_pj, rtol=1e-6)
