"""Synthetic pipelines: determinism, resumability, learnable structure."""
import numpy as np

from repro.data.synthetic import SyntheticLM, SyntheticImages


def test_lm_batches_deterministic_and_resumable():
    d = SyntheticLM(vocab_size=128, seq_len=32, batch_size=4, seed=7)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_labels_are_next_tokens_mostly_predictable():
    d = SyntheticLM(vocab_size=128, seq_len=64, batch_size=8, seed=0)
    b = d.batch_at(0)
    # the affine map holds for ~90% of transitions (10% noise flips)
    pred = (b["tokens"] * 31 + b["labels"][:, :1] * 0) % 128   # a=31
    # recover b from one known transition instead: check consistency rate of
    # the affine rule across the batch
    t, l = b["tokens"], b["labels"]
    consistent = np.mean((l == (t * 31 + (l[0, 0] - t[0, 0] * 31) % 128) % 128))
    assert consistent > 0.7


def test_lm_host_sharding_changes_data():
    d0 = SyntheticLM(vocab_size=64, seq_len=16, batch_size=4, host_id=0)
    d1 = SyntheticLM(vocab_size=64, seq_len=16, batch_size=4, host_id=1)
    assert not np.array_equal(d0.batch_at(0)["tokens"],
                              d1.batch_at(0)["tokens"])


def test_images_classes_distinct_and_split():
    d = SyntheticImages(num_classes=4, image_size=16)
    b = d.batch(64, 0)
    assert b["images"].shape == (64, 16, 16, 3)
    assert b["images"].min() >= 0 and b["images"].max() <= 1
    means = [b["images"][b["labels"] == c].mean(axis=0)
             for c in range(4) if (b["labels"] == c).any()]
    # class templates differ
    diffs = [np.abs(means[i] - means[j]).mean()
             for i in range(len(means)) for j in range(i)]
    assert min(diffs) > 0.02
    tr = d.batch(32, 0, split="train")["images"]
    te = d.batch(32, 0, split="test")["images"]
    assert not np.allclose(tr, te)
