"""Fused paged-attention decode kernel: parity, serving property harness,
ring-mask boundary arithmetic, length-clamped views, and KV-read accounting.

Layers of coverage, bottom up:

* kernel parity — interpret-mode pallas (online-softmax chunk walk) vs the
  jnp reference (one-shot masked softmax over the gathered view): ulp-level
  agreement, and both must match a dense softmax oracle to fp32 rounding,
  across block sizes, GQA group widths, softcaps, zero-block table entries,
  and partial last blocks.
* serving property harness — same style as tests/test_kv_paged.py: the fused
  engine must be token-identical (temperature 0) to the contiguous cache
  across randomized arrival patterns / prompt lengths / block sizes, in ideal
  mode and (with QuantConfig(a_per_row=True)) analog mode, on both the
  interpret-mode pallas path and the jnp reference.
* the length-clamped gather fallback stays *bit*-identical: the positions a
  clamp drops are exactly the causally-masked ones, whose softmax terms are
  exact zeros.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.models import lm
from repro.models.attention import paged_attn_plan
from repro.models.common import NEG_INF
from repro.models.context import Ctx
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest, view_bucket
from repro.serve.kv_pool import PagedKV


# ---------------------------------------------------------------------------
# kernel parity: interpret-mode pallas vs jnp reference vs dense oracle
# ---------------------------------------------------------------------------
def _dense_oracle(q, kp, vp, table, mask, softcap=0.0):
    """Materialized-gather + one-shot softmax (the fallback path's math)."""
    B, KV, G, hd = q.shape
    bs = kp.shape[1]
    L = mask.shape[1]
    kv = kp[table].reshape(B, -1, KV, hd)[:, :L]
    vv = vp[table].reshape(B, -1, KV, hd)[:, :L]
    s = jnp.einsum("bkgh,bskh->bkgs", q, kv,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + mask[:, None, None, :]
    return jnp.einsum("bkgs,bskh->bkgh", jax.nn.softmax(s, axis=-1), vv,
                      preferred_element_type=jnp.float32)


def _case(rng, B, KV, G, hd, bs, T, L):
    NB = B * T + 1
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    vp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    table = jnp.asarray(rng.integers(0, NB, size=(B, T)), jnp.int32)
    table = table.at[:, -1].set(NB)          # unallocated tail -> zero block
    idx = jnp.asarray(rng.integers(0, L, size=B), jnp.int32)
    mask = jnp.where(jnp.arange(L)[None, :] <= idx[:, None], 0.0,
                     NEG_INF).astype(jnp.float32)
    return q, kp, vp, table, mask


@pytest.mark.parametrize("bs,KV,G", [(2, 1, 4), (4, 2, 2), (8, 2, 1)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_kernel_matches_ref_and_oracle(bs, KV, G, softcap):
    rng = np.random.default_rng(bs * 100 + KV * 10 + G)
    T = 4
    q, kp, vp, table, mask = _case(rng, B=3, KV=KV, G=G, hd=16, bs=bs, T=T,
                                   L=T * bs)
    y_ref = ops.paged_attention(q, kp, vp, table, mask, softcap=softcap,
                                impl="ref")
    y_int = ops.paged_attention(q, kp, vp, table, mask, softcap=softcap,
                                impl="interpret")
    # kernel walks chunks online, ref is a one-shot masked softmax: parity
    # is ulp-level, not bit-exact (same idiom as test_kernels.py for the
    # EMT matmul kernels)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               atol=2e-6)
    # both agree with the one-shot-softmax dense oracle to fp32 rounding
    y_d = _dense_oracle(q, kp, vp, table, mask, softcap)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_d), atol=2e-6)


def test_kernel_partial_last_block():
    """Logical length not a block multiple (ring window 6 paged at bs=4):
    the wrapper masks the rounding tail with NEG_INF."""
    rng = np.random.default_rng(7)
    q, kp, vp, table, mask = _case(rng, B=2, KV=2, G=2, hd=16, bs=4, T=2, L=8)
    mask = mask[:, :6]
    y_ref = ops.paged_attention(q, kp, vp, table, mask, impl="ref")
    y_int = ops.paged_attention(q, kp, vp, table, mask, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(y_ref),
                               np.asarray(_dense_oracle(q, kp, vp, table,
                                                        mask)), atol=2e-6)


def test_neg_inf_sentinel_is_shared():
    """The kernel stack's mask-sentinel threshold must match the sentinel
    models/common.py writes into mask rows (kernels cannot import models, so
    the tie is enforced here)."""
    from repro.kernels.paged_attention import NEG_INF as KERNEL_NEG_INF
    assert KERNEL_NEG_INF == NEG_INF


def test_kernel_fully_masked_row_is_finite():
    """A row whose mask is all NEG_INF (idle slot / zero-length encoder) must
    produce zeros, not NaN (the normalizer guard)."""
    rng = np.random.default_rng(3)
    q, kp, vp, table, mask = _case(rng, B=2, KV=1, G=2, hd=8, bs=4, T=2, L=8)
    mask = mask.at[1].set(NEG_INF)
    for impl in ("ref", "interpret"):
        y = np.asarray(ops.paged_attention(q, kp, vp, table, mask, impl=impl))
        assert np.isfinite(y).all()
        np.testing.assert_array_equal(y[1], 0.0)


# ---------------------------------------------------------------------------
# serving property harness (fused engine vs contiguous, randomized schedules)
# ---------------------------------------------------------------------------
MAX_LEN = 24
BATCH = 3


def _harness_cfg(emt, impl):
    # one ring (window 8) + one global layer: both fused table paths
    cfg = get_config("gemma3-1b", emt_mode="analog" if emt == "analog"
                     else "ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("local", "global"))
    if emt == "analog":
        # per-row DAC scale: analog equivalence is occupancy-independent
        cfg = cfg.replace(emt=cfg.emt.replace(
            quant=dataclasses.replace(cfg.emt.quant, a_per_row=True)))
    if impl is None:
        cfg = cfg.replace(fused_paged_attn=False)
    else:
        cfg = cfg.replace(paged_attn_impl=impl)
    return cfg


def _run_schedule(eng, reqs, arrivals):
    assert not eng.scheduler.busy
    order = sorted(range(len(reqs)), key=lambda i: (arrivals[i], i))
    rid_to_idx, results, step = {}, [], 0
    while order or eng.scheduler.busy:
        while order and arrivals[order[0]] <= step:
            i = order.pop(0)
            rid_to_idx[eng.submit(reqs[i])] = i
        results += eng.step()
        step += 1
    return {rid_to_idx[r.rid]: r.tokens for r in results}


def _check(cfg, block_size, lens, max_new, arrivals, exact=True):
    rng = np.random.default_rng(sum(lens) + sum(arrivals) + block_size)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, int(L))
                       .astype(np.int32), max_new=int(n), seed=i)
            for i, (L, n) in enumerate(zip(lens, max_new))]
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    kw = dict(batch_size=BATCH, max_len=MAX_LEN, seed=7, fresh_noise=False)
    want = _run_schedule(ServingEngine(cfg, params, **kw), reqs, arrivals)
    got = _run_schedule(ServingEngine(cfg, params, paged=True,
                                      block_size=block_size, **kw),
                        reqs, arrivals)
    for i in want:
        np.testing.assert_array_equal(
            got[i], want[i],
            err_msg=f"paged(bs={block_size}) diverged on request {i}")


@pytest.mark.parametrize("emt,impl", [
    ("ideal", "ref"), ("ideal", "interpret"),
    ("analog", "ref"), ("analog", "interpret"),
])
def test_fused_property_harness(emt, impl):
    """Fused paged decode is token-identical to the contiguous cache at
    temperature 0 under randomized arrivals — ideal + analog(a_per_row), on
    the jnp reference and the interpret-mode pallas kernel."""
    cfg = _harness_cfg(emt, impl)
    rng = np.random.default_rng(0 if emt == "ideal" else 1)
    trials = 2 if impl == "ref" else 1       # interpret emulation is slow
    for _ in range(trials):
        n = int(rng.integers(2, 5))
        lens = rng.integers(1, 11, size=n).tolist()
        max_new = rng.integers(1, 7, size=n).tolist()
        arrivals = np.sort(rng.integers(0, 6, size=n)).tolist()
        _check(cfg, int(rng.choice([4, 8])), lens, max_new, arrivals)


def test_clamped_gather_fallback_property():
    """With the fused kernel off, the (now length-clamped) gather fallback
    must still be token-identical — clamping only drops exact-zero terms."""
    cfg = _harness_cfg("ideal", None)
    _check(cfg, 4, lens=[5, 3, 9, 2], max_new=[6, 8, 4, 6],
           arrivals=[0, 0, 2, 5])


def test_fused_plan_report():
    plan = paged_attn_plan(_harness_cfg("ideal", "ref"))
    assert len(plan) == 2 and all("fused paged kernel [ref]" in r
                                  for _, r in plan)
    plan = paged_attn_plan(_harness_cfg("ideal", None))
    assert all("gather fallback" in r for _, r in plan)
    # M-RoPE no longer falls back: the kernel consumes post-RoPE q/k and
    # token-index mask rows, so position streams never reach it.  Zero
    # fallback layers on every shipped config (ISSUE 6 satellite).
    mrope = get_config("qwen2-vl-72b", emt_mode="ideal", smoke=True)
    assert all("fused paged kernel" in r for _, r in paged_attn_plan(mrope))
    from repro.configs import ARCHS
    for name in ARCHS:
        cfg = get_config(name, emt_mode="ideal", smoke=True)
        assert not any("fallback" in r for _, r in paged_attn_plan(cfg)), name


# ---------------------------------------------------------------------------
# ring-paged mask arithmetic at window boundaries
# ---------------------------------------------------------------------------
def _ring_mask_row(idx, win):
    """The exact arithmetic of models/attention.py's ring-paged decode mask."""
    k_pos = idx - np.mod(idx - np.arange(win), win)
    return k_pos >= 0, k_pos


@pytest.mark.parametrize("idx", [7, 8, 9, 15, 16, 17])
def test_ring_mask_boundary_arithmetic(idx, win=8):
    """At idx == win +/- 1 the ring wraps: slot s must hold position
    idx - ((idx - s) mod win), visible iff that position exists (>= 0)."""
    vis, k_pos = _ring_mask_row(idx, win)
    for s in range(win):
        # the slot written at position p is p % win; the *latest* position
        # mapping to slot s that is <= idx:
        expect_pos = idx - ((idx - s) % win)
        assert k_pos[s] == expect_pos
        assert vis[s] == (expect_pos >= 0)
    # exactly min(idx + 1, win) positions are visible
    assert vis.sum() == min(idx + 1, win)


@pytest.mark.parametrize("start", [6, 7, 8, 9])
def test_ring_paged_decode_across_window_boundary(start, win=8):
    """Paged ring decode must track contiguous ring decode bit-exactly (gather
    fallback) while idx crosses the window: start..start+3 covers idx == win,
    win +/- 1 for each parametrized start."""
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("local", "local"),
                      fused_paged_attn=False)
    assert cfg.sliding_window == win
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(1))
    B, max_len, bs = 2, 16, 4
    ctx = Ctx(seed=jnp.uint32(0))
    cache_c = lm.init_cache(cfg, B, max_len)
    kv = PagedKV(B, max_len, bs, num_blocks=2 * (max_len // bs), ring_len=win,
                 num_ring_blocks=2 * (win // bs))
    assert kv.admit(0, start, 8) and kv.admit(1, start, 8)
    cache_p = lm.init_paged_cache(cfg, B, max_len, bs,
                                  2 * (max_len // bs), 2 * (win // bs))
    tg, tl = kv.gather_tables()
    tables = {"global": jnp.asarray(tg), "local": jnp.asarray(tl)}
    lens = lm.paged_lens(cfg, max_len)
    rng = np.random.default_rng(start)
    cfg_fused = cfg.replace(fused_paged_attn=True, paged_attn_impl="ref")
    cache_f = jax.tree.map(jnp.copy, cache_p)
    for idx in range(start, start + 4):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, B), jnp.int32)
        l_c, cache_c, _ = lm.decode_step(params, cache_c, toks, idx, cfg, ctx)
        l_p, cache_p, _ = lm.decode_step(params, cache_p, toks, idx, cfg, ctx,
                                         page_tables=tables, page_lens=lens)
        np.testing.assert_array_equal(np.asarray(l_c), np.asarray(l_p),
                                      err_msg=f"ring gather diverged idx={idx}")
        l_f, cache_f, _ = lm.decode_step(params, cache_f, toks, idx, cfg_fused,
                                         ctx, page_tables=tables,
                                         page_lens=lens)
        np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_c),
                                   atol=1e-4, rtol=1e-5,
                                   err_msg=f"ring fused diverged idx={idx}")


def test_ring_prompt_longer_than_window():
    """Prompt length > window: the ring keeps only the tail; paged-fused and
    contiguous engines must agree token-for-token."""
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("local", "global"),
                      paged_attn_impl="ref")
    _check(cfg, 4, lens=[12, 14], max_new=[6, 5], arrivals=[0, 1])


# ---------------------------------------------------------------------------
# length-clamped views
# ---------------------------------------------------------------------------
def test_view_bucket():
    assert view_bucket(1, 4, 24) == 4
    assert view_bucket(5, 4, 24) == 8
    assert view_bucket(9, 4, 24) == 16
    assert view_bucket(17, 4, 24) == 24      # pow2 32 > max_len: cap
    assert view_bucket(24, 4, 24) == 24
    assert view_bucket(5, 16, 16) == 16
    assert view_bucket(3, 8, 64) == 8


def _clamp_setup():
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("local", "global"),
                      fused_paged_attn=False)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(2))
    B, max_len, bs = 2, 32, 4
    kv = PagedKV(B, max_len, bs, num_blocks=2 * (max_len // bs), ring_len=8,
                 num_ring_blocks=4)
    assert kv.admit(0, 8, 8) and kv.admit(1, 4, 8)
    for slot, upto in ((0, 12), (1, 8)):
        for p in range(upto):
            kv.ensure(slot, p)
    cache = lm.init_paged_cache(cfg, B, max_len, bs, 2 * (max_len // bs), 4)
    return cfg, params, kv, cache, max_len, bs


def test_clamped_view_is_bit_identical():
    """Gather fallback: clamping the logical view to the live block-rounded
    bucket must not change logits or cache writes at all — dropped positions
    are exactly the causally-masked zero-contribution ones."""
    cfg, params, kv, cache, max_len, bs = _clamp_setup()
    ctx = Ctx(seed=jnp.uint32(0))
    toks = jnp.asarray([11, 22], jnp.int32)
    idx = jnp.asarray([11, 7], jnp.int32)
    tg, tl = kv.gather_tables()
    lens_full = lm.paged_lens(cfg, max_len)
    vlen = view_bucket(12, bs, max_len)
    assert vlen == 16
    lens_cl = lm.clamped_lens(lens_full, vlen)
    assert lens_cl["global"] == 16 and lens_cl["local"] == lens_full["local"]
    full = lm.decode_step(params, cache, toks, idx, cfg, ctx,
                          page_tables={"global": jnp.asarray(tg),
                                       "local": jnp.asarray(tl)},
                          page_lens=lens_full)
    cl = lm.decode_step(params, jax.tree.map(jnp.copy, cache), toks, idx, cfg,
                        ctx,
                        page_tables={"global": jnp.asarray(tg[:, :vlen // bs]),
                                     "local": jnp.asarray(tl)},
                        page_lens=lens_cl)
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(cl[0]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), full[1], cl[1])


def test_engine_records_clamped_view():
    """The engine's decode steps run at the bucketed view length, not
    max_len, when live requests are short."""
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2,
                      layer_pattern=("local", "global"), paged_attn_impl="ref")
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=64, paged=True,
                        block_size=4, fresh_noise=False)
    rng = np.random.default_rng(0)
    eng.serve([GenRequest(prompt=rng.integers(0, cfg.vocab_size, 5)
                          .astype(np.int32), max_new=4, seed=0)])
    # chunked prefill at exact positions: 5-token prompt + 3 decode steps ->
    # positions < 8 -> 8-view bucket (the pow2 prompt bucket is gone)
    assert eng.view_len == 8 < eng.max_len


# ---------------------------------------------------------------------------
# kv-read accounting (padded positions must not bill)
# ---------------------------------------------------------------------------
def _kv_reads_setup(fused_impl):
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=1,
                      layer_pattern=("global",), sliding_window=0)
    if fused_impl is None:
        cfg = cfg.replace(fused_paged_attn=False)
    else:
        cfg = cfg.replace(paged_attn_impl=fused_impl)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(3))
    return cfg, params


@pytest.mark.parametrize("fused_impl", [None, "ref"])
def test_kv_reads_bill_only_visible_positions(fused_impl):
    """aux["kv_reads"] counts mask-visible K/V elements: sum(idx+1) positions
    x kv_heads x head_dim x 2 — identical for fused and gather paths, and
    invariant to clamping (the clamp only drops already-masked positions)."""
    cfg, params = _kv_reads_setup(fused_impl)
    B, max_len, bs = 2, 32, 4
    kv = PagedKV(B, max_len, bs, num_blocks=2 * (max_len // bs))
    assert kv.admit(0, 8, 8) and kv.admit(1, 4, 8)
    for p in range(10):                      # cover the write positions
        kv.ensure(0, p)
    for p in range(4):
        kv.ensure(1, p)
    cache = lm.init_paged_cache(cfg, B, max_len, bs, 2 * (max_len // bs))
    tg, tl = kv.gather_tables()
    ctx = Ctx(seed=jnp.uint32(0))
    toks = jnp.asarray([1, 2], jnp.int32)
    idx = jnp.asarray([9, 3], jnp.int32)
    expect = (10 + 4) * cfg.num_kv_heads * cfg.head_dim * 2
    lens = lm.paged_lens(cfg, max_len)
    for vlen in (max_len, 16):
        width = -(-vlen // bs)
        _, _, aux = lm.decode_step(
            params, jax.tree.map(jnp.copy, cache), toks, idx, cfg, ctx,
            page_tables={"global": jnp.asarray(tg[:, :width]),
                         "local": jnp.asarray(tl)},
            page_lens=lm.clamped_lens(lens, vlen))
        assert float(aux["kv_reads"]) == expect, (vlen, fused_impl)


def test_kv_reads_contiguous_decode_matches_paged():
    """The contiguous decode path bills the same visible-position count."""
    cfg, params = _kv_reads_setup(None)
    B, max_len = 2, 32
    cache = lm.init_cache(cfg, B, max_len)
    ctx = Ctx(seed=jnp.uint32(0))
    _, _, aux = lm.decode_step(params, cache, jnp.asarray([1, 2], jnp.int32),
                               jnp.asarray([9, 3], jnp.int32), cfg, ctx)
    assert float(aux["kv_reads"]) == \
        (10 + 4) * cfg.num_kv_heads * cfg.head_dim * 2


# ---------------------------------------------------------------------------
# flash-style chunked-prefill kernel (kernels/paged_prefill.py)
# ---------------------------------------------------------------------------
def _prefill_lane_oracle(q, kp, vp, table, qpos, softcap=0.0):
    """Per-lane dense oracle: each chunk lane is a decode query whose mask is
    the causal row arange(L) <= qpos — the exact math the legacy
    write-then-gather path ran through `_gqa_core`."""
    B, C, H, hd = q.shape
    KV = kp.shape[2]
    G = H // KV
    L = table.shape[1] * kp.shape[1]
    outs = []
    for c in range(C):
        mask = jnp.where(jnp.arange(L)[None, :] <= qpos[:, c][:, None], 0.0,
                         NEG_INF).astype(jnp.float32)
        o = _dense_oracle(q[:, c].reshape(B, KV, G, hd), kp, vp, table, mask,
                          softcap)
        outs.append(o.reshape(B, H * hd))
    return jnp.stack(outs, axis=1)


def _prefill_case(rng, B, KV, G, hd, bs, T, C):
    """Phase-mixed chunk: random per-row ntok in [1, C] (1 == decode-phase
    row riding along), random starts landing mid-block (partial blocks)."""
    NB = B * T + 1
    H = KV * G
    q = jnp.asarray(rng.normal(size=(B, C, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    vp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    table = jnp.asarray(rng.integers(0, NB, size=(B, T)), jnp.int32)
    table = table.at[:, -1].set(NB)          # unallocated tail -> zero block
    ntok = rng.integers(1, C + 1, size=B)
    start = rng.integers(0, T * bs - C, size=B)
    j = np.arange(C)[None, :]
    qpos = jnp.asarray(start[:, None] + np.minimum(j, ntok[:, None] - 1),
                       jnp.int32)
    return q, kp, vp, table, qpos


@pytest.mark.parametrize("bs,KV,G,C,softcap", [
    (4, 2, 2, 5, 0.0),     # partial blocks: starts/qpos land mid-block
    (8, 1, 3, 4, 30.0),    # softcap before the causal mask
    (2, 2, 1, 6, 0.0),     # tiny blocks: chunk spans many blocks
])
def test_prefill_kernel_parity_sweep(bs, KV, G, C, softcap):
    """interpret-mode prefill kernel vs jnp reference vs per-lane dense
    oracle, over phase-mixed batches with mid-block starts."""
    rng = np.random.default_rng(bs * 100 + KV * 10 + G + C)
    T = 5                                     # non-pow2: wrapper pads
    q, kp, vp, table, qpos = _prefill_case(rng, B=3, KV=KV, G=G, hd=16,
                                           bs=bs, T=T, C=C)
    y_ref = ops.paged_prefill(q, kp, vp, table, qpos, softcap=softcap,
                              impl="ref")
    y_int = ops.paged_prefill(q, kp, vp, table, qpos, softcap=softcap,
                              impl="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               atol=2e-6)
    y_d = _prefill_lane_oracle(q, kp, vp, table, qpos, softcap)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_d), atol=2e-6)


def test_prefill_kernel_chunk_skip_boundary():
    """Rows whose furthest visible position sits exactly at a block-chunk
    span edge: the kernel's per-row chunk skip must include the boundary
    chunk and exclude the ones past it (off-by-one hazard)."""
    rng = np.random.default_rng(11)
    B, KV, G, hd, bs, T, C = 3, 1, 2, 8, 4, 256, 2   # span = 512 positions
    NB = 300
    H = KV * G
    q = jnp.asarray(rng.normal(size=(B, C, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    vp = jnp.asarray(rng.normal(size=(NB + 1, bs, KV, hd)),
                     jnp.float32).at[NB].set(0.0)
    table = jnp.asarray(rng.integers(0, NB, size=(B, T)), jnp.int32)
    span = ops.pick_block_chunk(T, bs, head_dim=hd) * bs
    assert span < T * bs                      # multiple grid chunks
    # qlast one-below / at / one-past the first chunk edge per row
    qpos = jnp.asarray([[span - 2, span - 1],
                        [span - 1, span],
                        [span, span + 1]], jnp.int32)
    y_ref = ops.paged_prefill(q, kp, vp, table, qpos, impl="ref")
    y_int = ops.paged_prefill(q, kp, vp, table, qpos, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               atol=2e-6)


def test_pick_block_chunk_occupancy():
    """Narrow (low-occupancy) views run in one grid step; wide views cap at
    the ~512-position VMEM-bounded chunk; always a power of two."""
    assert ops.pick_block_chunk(0, 16) == 1
    assert ops.pick_block_chunk(1, 16) == 1
    assert ops.pick_block_chunk(2, 16) == 2         # whole view, one step
    assert ops.pick_block_chunk(3, 16) == 4         # pow2 ceil of width
    assert ops.pick_block_chunk(64, 16) == 32       # 512-position cap
    assert ops.pick_block_chunk(256, 4) == 128
    for w in (1, 2, 5, 17, 63, 200):
        c = ops.pick_block_chunk(w, 8)
        assert c & (c - 1) == 0                      # pow2


# ---------------------------------------------------------------------------
# fused in-kernel cache write: pool bit-identity with the scatter path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("emt,impl", [
    ("ideal", "ref"), ("ideal", "interpret"), ("analog", "ref"),
    ("analog", "interpret"),
])
@pytest.mark.parametrize("pattern", [("global",), ("local",)])
def test_fused_write_pool_bit_identity(emt, impl, pattern):
    """Decode with the fused in-kernel write must leave the K/V pools
    BIT-identical to the scatter + gather fallback after every step — same
    values, same dtype cast, same inactive-row drop — under ideal and analog
    per-row DAC quant, and produce the same argmax token.

    Single-layer stacks on purpose: the written K/V rows then derive from
    identical inputs on both paths (embeddings), isolating the write
    mechanism.  In deeper stacks attend outputs differ at ulp level (online
    vs one-shot softmax), so later layers' *projected* K/V differs at ulp —
    that path is covered by the token-identity harness above."""
    cfg_f = _harness_cfg(emt, impl).replace(num_layers=1,
                                            layer_pattern=pattern)
    cfg_s = _harness_cfg(emt, None).replace(num_layers=1,
                                            layer_pattern=pattern)
    params = init_params(lm.specs(cfg_f), jax.random.PRNGKey(4))
    B, max_len, bs, win = 2, 16, 4, 8
    kv = PagedKV(B, max_len, bs, num_blocks=2 * (max_len // bs), ring_len=win,
                 num_ring_blocks=2 * (win // bs))
    assert kv.admit(0, 5, 8) and kv.admit(1, 2, 8)
    starts = [5, 2]
    for slot, s0 in enumerate(starts):
        for p in range(s0 + 4):
            kv.ensure(slot, p)
    cache_f = lm.init_paged_cache(cfg_f, B, max_len, bs,
                                  2 * (max_len // bs), 2 * (win // bs))
    cache_s = jax.tree.map(jnp.copy, cache_f)
    tg, tl = kv.gather_tables()
    tables = {"global": jnp.asarray(tg), "local": jnp.asarray(tl)}
    lens = lm.paged_lens(cfg_f, max_len)
    ctx = Ctx(seed=jnp.uint32(0))
    rng = np.random.default_rng(9)
    active = jnp.asarray([True, True])
    for t in range(4):
        toks = jnp.asarray(rng.integers(0, cfg_f.vocab_size, B), jnp.int32)
        idx = jnp.asarray([starts[0] + t, starts[1] + t], jnp.int32)
        if t == 3:                      # freeze row 1: inactive rows must
            active = jnp.asarray([True, False])       # not write (drop)
        l_f, cache_f, _ = lm.decode_step(params, cache_f, toks, idx, cfg_f,
                                         ctx, active=active,
                                         page_tables=tables, page_lens=lens)
        l_s, cache_s, _ = lm.decode_step(params, cache_s, toks, idx, cfg_s,
                                         ctx, active=active,
                                         page_tables=tables, page_lens=lens)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"pool diverged from scatter path at step {t}"),
            cache_f, cache_s)
        np.testing.assert_array_equal(
            np.argmax(np.asarray(l_f), -1), np.argmax(np.asarray(l_s), -1),
            err_msg=f"token diverged at step {t}")


# ---------------------------------------------------------------------------
# chunked-prefill kv-read billing (padding lanes must not bill)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused_impl", [None, "ref", "interpret"])
def test_chunk_kv_reads_bill_valid_lanes_only(fused_impl):
    """Chunk-step billing counts mask-visible positions of REAL lanes only:
    sum over rows of sum_{i<ntok}(qpos_i + 1) x KV x hd x 2.  Padding lanes
    (clamped duplicate qpos rows) are compute filler, not reads — and the
    count is identical between the flash prefill kernel and the legacy
    gather path."""
    cfg, params = _kv_reads_setup(fused_impl)
    B, C, max_len, bs = 2, 4, 16, 4
    kv = PagedKV(B, max_len, bs, num_blocks=2 * (max_len // bs))
    assert kv.admit(0, 4, 4) and kv.admit(1, 7, 4)
    for p in range(4):
        kv.ensure(0, p)
    for p in range(7):
        kv.ensure(1, p)
    cache = lm.init_paged_cache(cfg, B, max_len, bs, 2 * (max_len // bs))
    tg, tl = kv.gather_tables()
    ctx = Ctx(seed=jnp.uint32(0))
    toks = jnp.asarray(np.arange(B * C).reshape(B, C), jnp.int32)
    start = jnp.asarray([0, 6], jnp.int32)
    ntok = jnp.asarray([4, 1], jnp.int32)    # prefill row + decode-phase row
    # row 0 lanes see 1+2+3+4 positions; row 1's single real lane sees 7;
    # its 3 padding lanes (clamped to qpos=6) must NOT add 3 x 7
    expect = (1 + 2 + 3 + 4 + 7) * cfg.num_kv_heads * cfg.head_dim * 2
    _, _, aux = lm.chunk_step(
        params, cache, toks, start, ntok, cfg, ctx,
        page_tables={"global": jnp.asarray(tg), "local": jnp.asarray(tl)},
        page_lens=lm.paged_lens(cfg, max_len))
    assert float(aux["kv_reads"]) == expect, fused_impl
