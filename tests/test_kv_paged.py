"""Cache-equivalence property harness: paged (block-table) decode must be
token-identical to the contiguous KV cache at temperature 0, for randomized
arrival patterns, prompt lengths, and block sizes (including blocks smaller
than a prompt bucket).

The paged cache reads through a per-request block table whose unallocated
entries resolve to a dedicated always-zero block, and freed blocks are zeroed
at retirement — so the gathered logical view is bit-identical to the
zero-initialized contiguous cache and greedy decode cannot diverge.

Engines are cached per geometry: each ServingEngine owns per-instance jitted
closures, so reusing them across cases keeps this module off the compile path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest

MAX_LEN = 24
BATCH = 3


def _cfg():
    # gemma3 smoke: 5 local (ring, window 8) + 1 global layer — both paged
    # decode table paths in one stack
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    return cfg.replace(dtype=jnp.float32, num_layers=6)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    engines = {}

    def engine(block_size=None):
        """Cached engine per geometry; block_size=None -> contiguous."""
        key = block_size
        if key not in engines:
            kw = {} if block_size is None else dict(paged=True,
                                                    block_size=block_size)
            engines[key] = ServingEngine(cfg, params, batch_size=BATCH,
                                         max_len=MAX_LEN, seed=7,
                                         fresh_noise=False, **kw)
        return engines[key]

    return cfg, engine


def _requests(cfg, rng, lens, max_new):
    return [GenRequest(prompt=rng.integers(0, cfg.vocab_size, int(L))
                       .astype(np.int32), max_new=int(n), seed=i)
            for i, (L, n) in enumerate(zip(lens, max_new))]


def _run_schedule(eng, reqs, arrivals):
    """Drive `eng` submitting reqs[i] before engine step arrivals[i]; returns
    {request index: generated tokens}."""
    assert not eng.scheduler.busy
    order = sorted(range(len(reqs)), key=lambda i: (arrivals[i], i))
    rid_to_idx, results, step = {}, [], 0
    while order or eng.scheduler.busy:
        while order and arrivals[order[0]] <= step:
            i = order.pop(0)
            rid_to_idx[eng.submit(reqs[i])] = i
        results += eng.step()
        step += 1
    assert len(results) == len(reqs)
    return {rid_to_idx[r.rid]: r.tokens for r in results}


def _check_equivalence(cfg, engine, block_size, lens, max_new, arrivals):
    rng = np.random.default_rng(sum(lens) + sum(arrivals) + block_size)
    reqs = _requests(cfg, rng, lens, max_new)
    want = _run_schedule(engine(None), reqs, arrivals)
    got = _run_schedule(engine(block_size), reqs, arrivals)
    for i in want:
        np.testing.assert_array_equal(
            got[i], want[i],
            err_msg=(f"paged(bs={block_size}) diverged on request {i} "
                     f"(lens={lens}, arrivals={arrivals})"))


def test_paged_matches_contiguous_staggered(setup):
    """Blocks smaller than the prompt bucket (4 < bucket 8), mixed prompt
    lengths, mid-decode backfill arrivals."""
    cfg, engine = setup
    _check_equivalence(cfg, engine, 4, lens=[5, 3, 7, 9, 2],
                       max_new=[6, 8, 5, 4, 6], arrivals=[0, 0, 1, 3, 5])


def test_paged_property_random_schedules(setup):
    """Randomized property harness (numpy-driven so it runs without
    hypothesis): random prompt lengths, decode budgets, and arrival steps."""
    cfg, engine = setup
    rng = np.random.default_rng(42)
    for trial in range(3):
        n = int(rng.integers(2, 6))
        lens = rng.integers(1, 11, size=n).tolist()
        max_new = rng.integers(1, 7, size=n).tolist()
        arrivals = np.sort(rng.integers(0, 7, size=n)).tolist()
        block_size = int(rng.choice([2, 4]))
        _check_equivalence(cfg, engine, block_size, lens, max_new, arrivals)


def test_paged_property_hypothesis(setup):
    """Same property under hypothesis, when available."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, engine = setup

    # max_examples inherited from the active profile (tests/conftest.py):
    # 6 under the tier-1 `ci` profile, 75 under `--hypothesis-profile=nightly`
    @settings(deadline=None)
    @given(st.data())
    def prop(data):
        block_size = data.draw(st.sampled_from([2, 4, 8]))
        n = data.draw(st.integers(2, 5))
        lens = data.draw(st.lists(st.integers(1, 10), min_size=n, max_size=n))
        max_new = data.draw(st.lists(st.integers(1, 6), min_size=n, max_size=n))
        arrivals = sorted(data.draw(
            st.lists(st.integers(0, 6), min_size=n, max_size=n)))
        _check_equivalence(cfg, engine, block_size, lens, max_new, arrivals)

    prop()


def test_paged_admission_queues_on_block_budget():
    """4 slots but blocks for ~2 concurrent requests: admission must gate on
    the free-block budget, queue the rest, and still serve everything with
    tokens identical to running each request alone.

    Runs in `ideal` mode: a block-starved pool *delays admissions*, i.e.
    changes batch occupancy, and under EMT analog mode the per-tensor
    activation-quantization (DAC) scale couples co-tenant rows at the LSB —
    an engine-wide property independent of paging (the paged-vs-contiguous
    tests above hold bit-exactly because default pools never delay an
    admission the contiguous engine would make). Ideal mode has no
    quantization, so occupancy independence is exact."""
    cfg = get_config("gemma3-1b", emt_mode="ideal", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=6)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = _requests(cfg, rng, lens=[5, 6, 4, 5], max_new=[4, 4, 4, 4])
    tight = ServingEngine(cfg, params, batch_size=4, max_len=16, seed=7,
                          fresh_noise=False, paged=True, block_size=4,
                          num_blocks=6, num_ring_blocks=8)
    for r in reqs:
        tight.submit(r)
    tight.step()
    # exact-position chunked admission: r0 (len 5, +3 decode) needs 2 blocks,
    # r1 (len 6) needs 2 + 1 reserved; 6 blocks => 2 live, r2 (2) must queue
    assert tight.scheduler.num_active == 2
    assert tight.scheduler.pending == 2
    got = {r.rid: r.tokens for r in tight.drain()}
    assert sorted(got) == [0, 1, 2, 3]
    tight.kv.check()
    assert tight.kv.pool_g.num_free == tight.kv.pool_g.num_blocks
    solo = ServingEngine(cfg, params, batch_size=1, max_len=16, seed=7,
                         fresh_noise=False)
    for rid in got:
        solo.submit(GenRequest(prompt=reqs[rid].prompt,
                               max_new=reqs[rid].max_new, seed=reqs[rid].seed))
        (res,) = solo.drain()
        np.testing.assert_array_equal(got[rid], res.tokens)


def test_paged_blocks_zeroed_on_retirement(setup):
    """Regression (stale-read fix): once every request retires, every pool
    block is zero — a recycled block can never leak its previous owner's K/V."""
    cfg, engine = setup
    eng = engine(4)
    rng = np.random.default_rng(9)
    eng.serve(_requests(cfg, rng, lens=[6, 9], max_new=[5, 4]), stagger=1)
    assert not eng.scheduler.busy
    eng.kv.check()
    for name, blk in eng.cache.items():
        for key, arr in blk.items():
            assert float(jnp.abs(arr).max()) == 0.0, \
                f"stale data left in {name}/{key} after retirement"


def test_paged_decode_step_scalar_index():
    """decode_step's scalar-or-vector index contract holds for the paged
    layout too: a lockstep scalar index must match the equivalent (B,)
    vector."""
    from repro.models.context import Ctx
    from repro.serve.kv_pool import PagedKV

    cfg = _cfg().replace(num_layers=2)       # ('local', 'local') ring layers
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(1))
    kv = PagedKV(batch_size=2, max_len=16, block_size=4, num_blocks=8,
                 ring_len=8, num_ring_blocks=4)
    assert kv.admit(0, 8, 4) and kv.admit(1, 8, 4)
    cache = lm.init_paged_cache(cfg, 2, 16, 4, 8, 4)
    tg, tl = kv.gather_tables()
    tables = {"global": jnp.asarray(tg), "local": jnp.asarray(tl)}
    lens = lm.paged_lens(cfg, 16)
    ctx = Ctx(seed=jnp.uint32(0))
    toks = jnp.asarray([3, 5], jnp.int32)
    l_sc, c_sc, _ = lm.decode_step(params, cache, toks, 6, cfg, ctx,
                                   page_tables=tables, page_lens=lens)
    l_ve, c_ve, _ = lm.decode_step(params, cache, toks,
                                   jnp.asarray([6, 6], jnp.int32), cfg, ctx,
                                   page_tables=tables, page_lens=lens)
    np.testing.assert_array_equal(np.asarray(l_sc), np.asarray(l_ve))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), c_sc, c_ve)


def test_paged_cross_attention_encdec():
    """Cross-attention K/V paged through the global block table (enc-dec).

    Both engines are also pinned to the reference lockstep prefill+decode
    path: the engines cache ck/cv zero-padded to max_len, so without the
    per-slot `enc_lens` cross mask they would attend phantom zero-K encoder
    positions and diverge from the reference (while agreeing with each
    other)."""
    from repro.models.context import Ctx

    cfg = get_config("seamless-m4t-medium", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, L)
                       .astype(np.int32), max_new=4, seed=i)
            for i, L in enumerate([5, 3])]

    def reference(req):
        from repro.serve.engine import prefill_bucket
        S = prefill_bucket(len(req.prompt))
        toks = np.zeros((1, S), np.int32)
        toks[0, S - len(req.prompt):] = req.prompt
        batch = {"tokens": jnp.asarray(toks),
                 "enc_embeds": jnp.zeros((1, S, cfg.d_model), jnp.float32)}
        ctx = Ctx(seed=jnp.uint32(3))
        cache, logits, _ = lm.prefill(params, batch, cfg, ctx,
                                      lm.init_cache(cfg, 1, 16))
        out, pos = [int(jnp.argmax(logits[0]))], S
        for _ in range(req.max_new - 1):
            logits, cache, _ = lm.decode_step(
                params, cache, jnp.asarray([out[-1]], jnp.int32), pos, cfg, ctx)
            out.append(int(jnp.argmax(logits[0])))
            pos += 1
        return out

    def run(batch_size, stagger, **kw):
        eng = ServingEngine(cfg, params, batch_size=batch_size, max_len=16,
                            seed=3, fresh_noise=False, **kw)
        return eng.serve([GenRequest(prompt=r.prompt, max_new=r.max_new,
                                     seed=r.seed) for r in reqs],
                         stagger=stagger)

    # co-tenant: paged and contiguous see the same occupancy -> identical
    want = run(2, 1)
    got = run(2, 1, paged=True, block_size=4)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(b.tokens, a.tokens)
    # solo (batch 1, run to completion before the next request): both engines
    # must reproduce the canonical prefill+decode_step path bit-exactly —
    # without the enc_lens cross mask the zero-padded ck/cv would diverge
    for kw in ({}, dict(paged=True, block_size=4)):
        for res, r in zip(run(1, 100, **kw), reqs):
            np.testing.assert_array_equal(res.tokens, reference(r))
