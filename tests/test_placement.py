"""Heterogeneous device-placement API: registry, rule resolution, dict
round-tripping (checkpoint metadata), old-config equivalence, and a mixed
(>= 3 corners) model end-to-end (train grad + serving with per-corner energy
that sums to the total)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config, mixed_placement
from repro.configs.common import emt_preset
from repro.core.device import (DeviceModel, get_device, register_device,
                               device_names)
from repro.core.emt_linear import IDEAL
from repro.core.placement import (DevicePlacement, LayerRule, as_placement,
                                  single, emt_for_corner, placement_to_dict,
                                  placement_from_dict, emt_to_dict,
                                  emt_from_dict)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import Ctx
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest

CTX = Ctx()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_presets_exist():
    for name in ("default", "pcm", "rram", "mlc2", "mlc4", "sram_digital"):
        assert name in device_names()
        assert isinstance(get_device(name), DeviceModel)
    assert get_device("mlc4").num_states == 4
    assert get_device("sram_digital").amplitude == 0.0


def test_registry_unknown_corner_raises():
    with pytest.raises(KeyError, match="unknown device corner"):
        get_device("vaporware")
    with pytest.raises(KeyError):
        emt_for_corner("vaporware")


def test_register_device_no_silent_overwrite():
    dev = DeviceModel(amplitude=0.2)
    register_device("test_corner_x", dev)
    try:
        assert get_device("test_corner_x") is dev
        with pytest.raises(ValueError, match="already registered"):
            register_device("test_corner_x", DeviceModel())
        register_device("test_corner_x", DeviceModel(), overwrite=True)
    finally:
        from repro.core import device as device_mod
        device_mod._REGISTRY.pop("test_corner_x", None)


# ---------------------------------------------------------------------------
# rule resolution
# ---------------------------------------------------------------------------
def test_first_match_wins_on_overlapping_rules():
    pcm = emt_for_corner("pcm", "analog")
    rram = emt_for_corner("rram", "bitserial")
    p = DevicePlacement(rules=(LayerRule("*/attn/wq", pcm),
                               LayerRule("*/attn/*", rram)),
                        default=IDEAL)
    # overlapping patterns: the earlier (more specific here) rule wins
    assert p.resolve("dec/layer_000/attn/wq") is pcm
    assert p.resolve("dec/layer_000/attn/wk") is rram
    assert p.resolve("dec/layer_000/mlp/wg") is IDEAL
    # reversed order: the broad rule shadows the specific one
    q = DevicePlacement(rules=(LayerRule("*/attn/*", rram),
                               LayerRule("*/attn/wq", pcm)),
                        default=IDEAL)
    assert q.resolve("dec/layer_000/attn/wq") is rram


def test_match_is_explicit_rules_only():
    p = single(emt_preset("analog"))
    assert p.match("dec/layer_000/moe/router") is None     # default not applied
    assert p.resolve("dec/layer_000/moe/router").active
    q = DevicePlacement(rules=(LayerRule("*/moe/router",
                                         emt_for_corner("sram_digital",
                                                        "analog")),),
                        default=emt_preset("analog"))
    assert q.match("dec/layer_003/moe/router").corner == "sram_digital"


def test_as_placement_wraps_and_passes_through():
    emt = emt_preset("analog")
    p = as_placement(emt)
    # equality, not identity: as_placement caches wraps by config value
    assert isinstance(p, DevicePlacement) and p.default == emt and not p.rules
    assert as_placement(p) is p
    with pytest.raises(TypeError):
        as_placement({"mode": "analog"})


def test_placement_corners_and_active():
    p = mixed_placement()
    assert set(p.corners()) == {"pcm", "rram", "sram_digital"}
    assert p.active and p.mode == "analog"
    assert not single(IDEAL).active


# ---------------------------------------------------------------------------
# dict serialization (checkpoint extra metadata)
# ---------------------------------------------------------------------------
def test_emt_dict_roundtrip():
    for emt in (IDEAL, emt_preset("analog"), emt_preset("bitserial"),
                emt_for_corner("mlc4", "analog", intensity="strong")):
        back = emt_from_dict(emt_to_dict(emt))
        assert back == emt


def test_placement_dict_roundtrip_through_checkpoint(tmp_path):
    import json
    p = mixed_placement()
    d = placement_to_dict(p)
    json.dumps(d)                                  # must be plain JSON
    assert placement_from_dict(d) == p
    # a plain EMTConfig serializes as its zero-rule wrap
    d1 = placement_to_dict(emt_preset("analog"))
    assert placement_from_dict(d1) == single(emt_preset("analog"))
    # through CheckpointManager extra metadata (meta.json is JSON on disk)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"w": jnp.zeros(2)}, extra={"placement": d})
    _, meta = mgr.restore(1, {"w": jnp.zeros(2)})
    assert placement_from_dict(meta["extra"]["placement"]) == p


def test_serialization_unknown_corner_and_field_errors():
    d = emt_to_dict(emt_preset("analog"))
    d["device"] = "vaporware"                      # registry reference form
    with pytest.raises(KeyError, match="unknown device corner"):
        emt_from_dict(d)
    with pytest.raises(ValueError, match="unknown DeviceModel fields"):
        emt_from_dict({**emt_to_dict(IDEAL),
                       "device": {"amplitude": 0.1, "bogus_knob": 3}})


def test_device_string_reference_resolves_from_registry():
    d = emt_to_dict(emt_for_corner("rram", "bitserial"))
    d["device"] = "rram"
    assert emt_from_dict(d).device == get_device("rram")


# ---------------------------------------------------------------------------
# equivalence: zero-rule wrap == old global EMTConfig, bit-identical
# ---------------------------------------------------------------------------
def _tiny_cfg(emt, **kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=48,
                num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
                head_dim=12, dtype=jnp.float32, emt=emt, remat=False)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("mode", ["ideal", "analog", "bitserial"])
def test_wrapped_placement_bit_identical_to_plain_config(mode):
    emt = emt_preset(mode)
    cfg_plain = _tiny_cfg(emt)
    cfg_wrap = _tiny_cfg(single(emt))
    params = init_params(lm.specs(cfg_plain), jax.random.PRNGKey(0))
    # identical param trees (same specs resolve everywhere)
    p2 = init_params(lm.specs(cfg_wrap), jax.random.PRNGKey(0))
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(p2)
    toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128))
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    ctx = Ctx(seed=3)
    loss_a, m_a = lm.train_loss(params, batch, cfg_plain, ctx)
    loss_b, m_b = lm.train_loss(params, batch, cfg_wrap, ctx)
    assert float(loss_a) == float(loss_b)
    assert float(m_a["energy_uj"]) == float(m_b["energy_uj"])
    # decode path too
    cache_a = lm.init_cache(cfg_plain, 2, 9)
    cache_b = lm.init_cache(cfg_wrap, 2, 9)
    ca, la, aux_a = lm.prefill(params, {"tokens": batch["tokens"]},
                               cfg_plain, ctx, cache_a)
    cb, lb, aux_b = lm.prefill(params, {"tokens": batch["tokens"]},
                               cfg_wrap, ctx, cache_b)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert float(aux_a["energy_pj"]) == float(aux_b["energy_pj"])
    da, _, _ = lm.decode_step(params, ca, jnp.asarray(toks[:, -1]), 8,
                              cfg_plain, ctx)
    db, _, _ = lm.decode_step(params, cb, jnp.asarray(toks[:, -1]), 8,
                              cfg_wrap, ctx)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


def test_corner_breakdown_sums_to_total_energy():
    cfg = _tiny_cfg(emt_preset("analog"))
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    toks = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    cache = lm.init_cache(cfg, 2, 9)
    _, _, aux = lm.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                           Ctx(seed=1), cache)
    total = float(aux["energy_pj"])
    by_corner = sum(float(c["energy_pj"]) for c in aux["corners"].values())
    assert total > 0
    np.testing.assert_allclose(by_corner, total, rtol=1e-6)


# ---------------------------------------------------------------------------
# mixed placement (3 corners) end-to-end: train grad + serve
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mixed_moe():
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True, placement="mixed")
    cfg = cfg.replace(dtype=jnp.float32, remat=False)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_mixed_placement_resolves_three_corners(mixed_moe):
    cfg, _ = mixed_moe
    plan = cfg.placement_plan()
    corners = {c for _, c, _ in plan}
    assert {"pcm", "rram", "sram_digital"} <= corners
    by_path = dict((p, (c, m)) for p, c, m in plan)
    assert by_path["dec/layer_000/attn/wq"] == ("pcm", "analog")
    assert any(p.endswith("/moe/experts") and c == ("rram", "bitserial")
               for p, c in [(p, v) for p, v in by_path.items()])
    assert any(p.endswith("/moe/router") and v == ("sram_digital", "analog")
               for p, v in by_path.items())


def test_plan_reports_unplaced_router_as_digital():
    """The plan must say what moe_specs/moe_ffn do: the default never pulls
    the router onto a crossbar, so without an explicit rule it is digital."""
    cfg = get_config("moonshot-v1-16b-a3b", emt_mode="analog", smoke=True)
    routers = [t for t in cfg.placement_plan() if t[0].endswith("/moe/router")]
    assert routers and all(t[1:] == ("digital", "fp32") for t in routers)


def test_mixed_placement_router_on_crossbar_has_rho(mixed_moe):
    cfg, params = mixed_moe
    moe_layers = [n for n, moe in zip(
        [f"layer_{i:03d}" for i in range(cfg.num_layers)],
        cfg.moe_layer_mask()) if moe]
    router = params["decoder"][moe_layers[0]]["ffn"]["router"]
    assert "rho_raw" in router                    # explicitly placed -> EMT


def test_mixed_placement_trains(mixed_moe):
    cfg, params = mixed_moe
    toks = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    def loss_fn(p):
        return lm.train_loss(p, batch, cfg, Ctx(seed=2), lam=1e-6)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = float(jnp.sqrt(sum(jnp.sum(g * g.conj()).real
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0
    total = float(metrics["energy_uj"])
    split = {k.split("/")[1]: float(v) for k, v in metrics.items()
             if k.startswith("energy_uj/")}
    assert set(split) == {"pcm", "rram", "sram_digital"}
    np.testing.assert_allclose(sum(split.values()), total, rtol=1e-5)


@pytest.mark.slow
def test_mixed_placement_serves_with_corner_energy(mixed_moe):
    cfg, params = mixed_moe
    eng = ServingEngine(cfg, params, batch_size=2, max_len=20,
                        fresh_noise=False)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, 6)
                       .astype(np.int32), max_new=4, seed=i)
            for i in range(3)]
    res = eng.serve(reqs, stagger=1)
    assert len(res) == 3 and all(len(r.tokens) == 4 for r in res)
    assert set(eng.corner_energy_pj) == {"pcm", "rram", "sram_digital"}
    np.testing.assert_allclose(sum(eng.corner_energy_pj.values()),
                               eng.total_energy_pj, rtol=1e-6)
    assert min(eng.corner_energy_pj.values()) > 0
