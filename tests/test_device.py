"""Device-model unit tests: RTN state normalization, sigma(rho), energy."""
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceModel, four_state_device, get_device


def test_states_unbiased_unit_variance():
    for dev in [DeviceModel(), four_state_device(),
                DeviceModel(state_offsets=(-3.0, 1.0), state_probs=(0.2, 0.8))]:
        a = np.asarray(dev.state_offsets)
        p = np.asarray(dev.state_probs)
        assert abs((p * a).sum()) < 1e-9          # unbiased reads
        assert abs((p * a * a).sum() - 1.0) < 1e-9  # unit relative variance
        assert abs(p.sum() - 1.0) < 1e-9


def test_sigma_decreases_with_rho():
    dev = DeviceModel()
    rhos = jnp.array([0.5, 1.0, 4.0, 16.0, 64.0])
    sig = dev.sigma_rel(rhos)
    assert bool(jnp.all(jnp.diff(sig) < 0))       # higher rho -> less fluctuation


def test_intensity_ordering():
    sigs = [DeviceModel(intensity=i).sigma_rel(4.0)
            for i in ("weak", "normal", "strong")]
    assert sigs[0] < sigs[1] < sigs[2]


def test_energy_proportional_to_rho_and_weight():
    dev = DeviceModel()
    e1 = dev.mac_energy(1.0, 100.0, 0.5, 10)
    e2 = dev.mac_energy(2.0, 100.0, 0.5, 10)
    e3 = dev.mac_energy(1.0, 200.0, 0.5, 10)
    assert np.isclose(e2, 2 * e1) and np.isclose(e3, 2 * e1)


def test_peripheral_energy_positive():
    dev = DeviceModel()
    assert dev.peripheral_energy(100) > 0


def test_read_value_two_state():
    dev = DeviceModel()
    lo = dev.read_value(1.0, 4.0, -1.0)
    hi = dev.read_value(1.0, 4.0, +1.0)
    sig = float(dev.sigma_rel(4.0))
    assert np.isclose(hi - lo, 2 * sig, rtol=1e-6)
    assert np.isclose((hi + lo) / 2, 1.0, rtol=1e-6)


# --- calibration pins (docs/device_models.md "Calibration") ---------------
# The analog presets are anchored to published measurements: Joshi et al.
# arXiv:1906.03138 (PCM: ~0.1 pJ/MAC array-level, ~1.5 pJ/conversion ADC,
# ~10x array-to-system gap -> the per-tile static term) and Yan et al.
# arXiv:2205.13018 (RRAM ~0.6x PCM energies, stronger flatter-in-rho
# fluctuation).  These pins make recalibration a deliberate act: changing a
# coefficient means redoing the derivation arithmetic in the doc.

def test_calibrated_preset_pins():
    pcm = get_device("pcm")
    assert (pcm.amplitude, pcm.beta) == (0.08, 0.5)
    assert (pcm.e_mac, pcm.e_read, pcm.e_static) == (0.0025, 200.0, 4000.0)
    # nominal operating point (rho=4, |w|=0.25, x_level=40): the cell term
    # recovers Joshi et al.'s ~0.1 pJ/MAC array-level figure
    assert np.isclose(pcm.e_mac * 4.0 * 0.25 * 40.0, 0.1)

    rram = get_device("rram")
    assert (rram.amplitude, rram.beta) == (0.14, 0.4)
    assert (rram.e_mac, rram.e_read, rram.e_static) == (0.0015, 120.0, 2400.0)
    # RRAM energies land at ~0.6x PCM (Yan et al.); fluctuation is stronger
    # and less suppressible by programming effort (higher amplitude, lower
    # beta)
    assert np.isclose(rram.e_mac / pcm.e_mac, 0.6)
    assert np.isclose(rram.e_read / pcm.e_read, 0.6)
    assert np.isclose(rram.e_static / pcm.e_static, 0.6)
    assert rram.amplitude > pcm.amplitude and rram.beta < pcm.beta

    for name in ("mlc2", "mlc4"):
        mlc = get_device(name)
        assert (mlc.e_mac, mlc.e_read, mlc.e_static) == (0.003, 250.0, 5000.0)
        assert mlc.e_mac > pcm.e_mac  # denser cells, harder sensing

    # the paper's reference corner is untouched: every pre-calibration
    # energy number in the repo stays bit-stable
    ref = get_device("default")
    assert (ref.amplitude, ref.beta) == (0.08, 0.5)
    assert (ref.e_mac, ref.e_read, ref.e_static) == (0.05, 0.4, 0.0)


def test_sram_digital_deterministic_and_static_free():
    sram = get_device("sram_digital")
    assert sram.amplitude == 0.0          # deterministic reads
    assert sram.e_static == 0.0           # clock-gated macro
    assert float(sram.sigma_rel(4.0)) == 0.0
    assert sram.static_energy(57.0) == 0.0


def test_static_energy_linear_in_tile_activations():
    pcm = get_device("pcm")
    assert pcm.static_energy(0.0) == 0.0
    assert np.isclose(pcm.static_energy(1.0), pcm.e_static)
    assert np.isclose(pcm.static_energy(7.5), 7.5 * pcm.e_static)
    # analog corners all carry a real static term
    for name in ("pcm", "rram", "mlc2", "mlc4"):
        assert get_device(name).e_static > 0.0
