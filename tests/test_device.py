"""Device-model unit tests: RTN state normalization, sigma(rho), energy."""
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceModel, four_state_device


def test_states_unbiased_unit_variance():
    for dev in [DeviceModel(), four_state_device(),
                DeviceModel(state_offsets=(-3.0, 1.0), state_probs=(0.2, 0.8))]:
        a = np.asarray(dev.state_offsets)
        p = np.asarray(dev.state_probs)
        assert abs((p * a).sum()) < 1e-9          # unbiased reads
        assert abs((p * a * a).sum() - 1.0) < 1e-9  # unit relative variance
        assert abs(p.sum() - 1.0) < 1e-9


def test_sigma_decreases_with_rho():
    dev = DeviceModel()
    rhos = jnp.array([0.5, 1.0, 4.0, 16.0, 64.0])
    sig = dev.sigma_rel(rhos)
    assert bool(jnp.all(jnp.diff(sig) < 0))       # higher rho -> less fluctuation


def test_intensity_ordering():
    sigs = [DeviceModel(intensity=i).sigma_rel(4.0)
            for i in ("weak", "normal", "strong")]
    assert sigs[0] < sigs[1] < sigs[2]


def test_energy_proportional_to_rho_and_weight():
    dev = DeviceModel()
    e1 = dev.mac_energy(1.0, 100.0, 0.5, 10)
    e2 = dev.mac_energy(2.0, 100.0, 0.5, 10)
    e3 = dev.mac_energy(1.0, 200.0, 0.5, 10)
    assert np.isclose(e2, 2 * e1) and np.isclose(e3, 2 * e1)


def test_peripheral_energy_positive():
    dev = DeviceModel()
    assert dev.peripheral_energy(100) > 0


def test_read_value_two_state():
    dev = DeviceModel()
    lo = dev.read_value(1.0, 4.0, -1.0)
    hi = dev.read_value(1.0, 4.0, +1.0)
    sig = float(dev.sigma_rel(4.0))
    assert np.isclose(hi - lo, 2 * sig, rtol=1e-6)
    assert np.isclose((hi + lo) / 2, 1.0, rtol=1e-6)
