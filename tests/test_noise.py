"""Technique A sampling: unbiasedness, amplitude, backend agreement in law."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device import DeviceModel
from repro.core.noise import NoiseConfig, fluctuate


@pytest.mark.parametrize("backend", ["hash", "threefry"])
def test_fluctuation_moments(backend):
    dev = DeviceModel()
    cfg = NoiseConfig(backend=backend)
    w = jnp.full((256, 256), 0.5)
    rho = 4.0
    samples = []
    for s in range(8):
        key = jax.random.PRNGKey(s) if backend == "threefry" else None
        samples.append(fluctuate(w, rho, dev, cfg, key=key, seed=s))
    ws = jnp.stack(samples)
    sig = float(dev.sigma_rel(rho))
    # unbiased: E[w~] == w ; std == sigma_rel * |w|
    assert abs(float(jnp.mean(ws)) - 0.5) < 0.5 * sig * 0.02 + 1e-4
    assert abs(float(jnp.std(ws)) - 0.5 * sig) < 0.5 * sig * 0.05


def test_disabled_noise_identity():
    dev = DeviceModel()
    w = jnp.ones((8, 8))
    out = fluctuate(w, 1.0, dev, NoiseConfig(enabled=False), seed=0)
    assert bool(jnp.all(out == w))


def test_rho_gradient_path():
    """d(output)/d(rho) must be nonzero — the optimizer tunes rho (Fig. 7)."""
    dev = DeviceModel()
    cfg = NoiseConfig(backend="hash")
    w = jnp.ones((32, 32))

    def f(rho):
        return jnp.sum(fluctuate(w, rho, dev, cfg, seed=1) ** 2)

    g = jax.grad(f)(4.0)
    assert np.isfinite(float(g)) and abs(float(g)) > 0


def test_per_step_samples_differ_across_seeds():
    dev = DeviceModel()
    cfg = NoiseConfig(backend="hash")
    w = jnp.ones((64, 64))
    a = fluctuate(w, 4.0, dev, cfg, seed=1)
    b = fluctuate(w, 4.0, dev, cfg, seed=2)
    assert float(jnp.mean((a == b).astype(jnp.float32))) < 0.6
