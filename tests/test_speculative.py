"""Heterogeneous speculative decoding: token identity + two-placement energy.

The control-plane PR's core property (docs/control_plane.md): a
`SpeculativeEngine` drafting on a `sram_digital` placement and verifying in
one all-lane analog chunk step commits *exactly* the tokens plain greedy
decode on the target placement would — under ideal EMT and under analog
with per-row DAC scales and frozen noise — while the energy ledger keeps
per-request + idle == total across **both** placements' corners, with the
draft/verify split carrying its own conservation invariant.  Cancellation
mid-decode and rejected drafts (a deliberately perturbed draft model) must
not break either property.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.emt_linear import IDEAL
from repro.core.placement import emt_for_corner
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import GenRequest, ServingEngine
from repro.serve.speculative import SpeculativeEngine

K = 3


def _base_cfg(**kw):
    # all-global stack (rejected drafts would clobber ring K/V) + ref paged
    # attention off the kernel path; float32 keeps argmax comparisons exact
    cfg = get_config("gemma3-1b", smoke=True, **kw)
    return cfg.replace(dtype=jnp.float32, num_layers=2,
                       layer_pattern=("attn",), sliding_window=0,
                       paged_attn_impl="ref")


def _pcm_cfg():
    # analog PCM target with per-row DAC scales: per-tensor activation quant
    # couples the verify lanes through the shared scale, so only a_per_row
    # guarantees bit-identity between a (k+1)-lane step and k+1 1-lane steps
    cfg = _base_cfg(emt_mode="analog")
    tgt = emt_for_corner("pcm")
    tgt = tgt.replace(quant=dataclasses.replace(tgt.quant, a_per_row=True))
    return cfg.replace(emt=tgt)


def _reqs(cfg, lens=((8, 16), (5, 10)), base_seed=0, **kw):
    out = []
    for i, (plen, max_new) in enumerate(lens):
        rng = np.random.default_rng(base_seed + i)
        out.append(GenRequest(
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new, **kw))
    return out


def _mk_spec(cfg, params, **kw):
    kw.setdefault("spec_k", K)
    return SpeculativeEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                             fresh_noise=False, **kw)


def _assert_conservation(eng, results):
    """Combined + per-corner + draft-split invariants over `results`, which
    must be *every* result the engine ever retired."""
    assert np.isclose(sum(r.energy_pj for r in results)
                      + eng.idle_energy_pj, eng.total_energy_pj, rtol=1e-6)
    assert np.isclose(sum(eng.corner_energy_pj.values()),
                      eng.total_energy_pj, rtol=1e-6)
    assert np.isclose(sum(r.draft_energy_pj for r in results)
                      + eng.draft_idle_energy_pj,
                      eng.draft_total_energy_pj, rtol=1e-6)
    # the draft subset is genuinely a subset, booked under its own corner
    assert eng.draft_total_energy_pj <= eng.total_energy_pj
    assert np.isclose(eng.corner_energy_pj.get("sram_digital", 0.0),
                      eng.draft_total_energy_pj, rtol=1e-6)


@pytest.fixture(scope="module")
def pcm():
    cfg = _pcm_cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    base = ServingEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                         fresh_noise=False)
    spec = _mk_spec(cfg, params)
    base_res = base.serve(_reqs(cfg))
    spec_res = spec.serve(_reqs(cfg))
    return dict(cfg=cfg, params=params, base=base, spec=spec,
                base_res=base_res, spec_res=spec_res,
                spec_history=list(spec_res))


def test_token_identity_analog_per_row(pcm):
    for a, b in zip(pcm["base_res"], pcm["spec_res"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert b.done_reason == a.done_reason
    spec = pcm["spec"]
    assert spec.spec_rounds > 0
    assert 0.0 < spec.accept_rate <= 1.0
    assert spec.accept_len_hist.sum() == spec.spec_rounds
    for r in pcm["spec_res"]:
        assert r.spec_proposed >= r.spec_accepted >= 0
        assert r.draft_energy_pj > 0.0


def test_two_placement_energy_conservation(pcm):
    # the combined ledger spans both engines' corners: analog pcm + digital
    # draft sum (with idle) to the one total, and the draft split conserves
    # on its own
    spec = pcm["spec"]
    assert set(spec.corner_energy_pj) >= {"pcm", "sram_digital"}
    assert spec.corner_energy_pj["pcm"] > 0.0
    assert spec.corner_energy_pj["sram_digital"] > 0.0
    _assert_conservation(spec, pcm["spec_res"])
    # plain engines never bill the draft corner or the split fields
    base = pcm["base"]
    assert "sram_digital" not in base.corner_energy_pj
    assert all(r.draft_energy_pj == 0.0 and r.spec_proposed == 0
               for r in pcm["base_res"])


def test_token_identity_staggered_admission(pcm):
    # staggered arrivals exercise mixed rounds (one slot streaming prompt
    # lanes through the verify chunk while the other speculates) and k_eff
    # clamping near per-request token budgets; identity must hold against
    # the *solo* baseline because a_per_row + frozen noise decouple
    # co-tenants — even though the spec engine splits the prompt across
    # several (k+1)-lane rounds where the baseline prefills it in one chunk
    reqs = _reqs(pcm["cfg"], lens=((6, 12), (9, 14)), base_seed=50)
    solo = pcm["base"].serve(_reqs(pcm["cfg"], lens=((6, 12),), base_seed=50))
    stag = pcm["spec"].serve(reqs, stagger=2)
    pcm["spec_history"].extend(stag)
    np.testing.assert_array_equal(solo[0].tokens, stag[0].tokens)
    _assert_conservation(pcm["spec"], pcm["spec_history"])


def test_rejected_drafts_keep_identity(pcm):
    # a deliberately perturbed draft model proposes junk some of the time:
    # the accept rate drops below 1 but every committed token is still the
    # target's greedy token — the rejected-lane K/V writes are provably
    # overwritten before any later query can attend them
    cfg, params = pcm["cfg"], pcm["params"]
    bad = jax.tree.map(
        lambda x: x * (1.0 + 0.05 * np.sin(np.arange(x.size, dtype=np.float32)
                                           .reshape(x.shape)))
        if x.dtype == jnp.float32 else x, params)
    spec = _mk_spec(cfg, params, draft_params=bad)
    res = spec.serve(_reqs(cfg))
    assert spec.accept_rate < 1.0
    for a, b in zip(pcm["base_res"], res):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    _assert_conservation(spec, res)


def test_token_identity_ideal_mode():
    # ideal params carry no rho_raw, so the draft must be an ideal placement
    # too — which makes draft and target the *same* computation: every
    # proposal must be accepted (accept rate exactly 1) and identity holds
    cfg = _base_cfg(emt_mode="ideal")
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(1))
    base = ServingEngine(cfg, params, batch_size=2, max_len=32, seed=7,
                         fresh_noise=False)
    spec = _mk_spec(cfg, params, draft_placement=IDEAL)
    rb = base.serve(_reqs(cfg))
    rs = spec.serve(_reqs(cfg))
    for a, b in zip(rb, rs):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert spec.spec_proposed_total > 0
    assert spec.accept_rate == 1.0


def test_paged_speculative_identity_and_hygiene(pcm):
    cfg, params = pcm["cfg"], pcm["params"]
    spec = _mk_spec(cfg, params, paged=True, block_size=4)
    res = spec.serve(_reqs(cfg))
    for a, b in zip(pcm["base_res"], res):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    _assert_conservation(spec, res)
    # verify writes stayed inside the admission-time block reservation and
    # the pool came back clean
    spec.kv.check()
    assert spec.kv.pool_g.num_owned == 0


def test_cancel_mid_decode_partials_and_draft_hygiene(pcm):
    spec = pcm["spec"]
    snap = (spec.total_energy_pj, spec.idle_energy_pj)
    reqs = _reqs(pcm["cfg"], lens=((8, 16), (8, 16)), base_seed=80)
    rids = [spec.submit(r) for r in reqs]
    results = []
    for _ in range(16):
        results += spec.step()
        if any(len(s.generated) >= 3 for _, s in
               spec.scheduler.active_slots()):
            break
    cancelled = spec.cancel(rids[0])
    assert cancelled is not None
    assert cancelled.done_reason == "cancelled"
    assert 0 < len(cancelled.tokens) < reqs[0].max_new
    results += [cancelled] + spec.drain()
    pcm["spec_history"].extend(results)
    # conservation holds with the cancelled partial: scenario-delta form
    d_total = spec.total_energy_pj - snap[0]
    d_idle = spec.idle_energy_pj - snap[1]
    assert np.isclose(sum(r.energy_pj for r in results) + d_idle, d_total,
                      rtol=1e-6)
    # zero-on-retire covers the draft shadow cache too: no rejected-draft
    # residue survives for a backfilled slot to attend
    for blk in spec.draft_cache.values():
        for arr in blk.values():
            assert float(jnp.abs(arr).max()) == 0.0


def test_guards():
    cfg, params = _guard_cfg_params()
    # sliding-window ring stacks are rejected: rejected-draft writes wrap
    # onto still-visible history that is never rewritten
    ring = get_config("gemma3-1b", smoke=True).replace(dtype=jnp.float32,
                                                       num_layers=2)
    assert "local" in ring.blocks() and ring.sliding_window
    with pytest.raises(ValueError, match="all-global"):
        SpeculativeEngine(ring, params, batch_size=2, max_len=32)
    with pytest.raises(ValueError, match="spec_k"):
        SpeculativeEngine(cfg, params, batch_size=2, max_len=32, spec_k=0)
    with pytest.raises(ValueError, match="prefix_cache"):
        SpeculativeEngine(cfg, params, batch_size=2, max_len=32, paged=True,
                          block_size=4, prefix_cache=True)
    with pytest.raises(ValueError, match="chunked"):
        SpeculativeEngine(cfg, params, batch_size=2, max_len=32,
                          chunked_prefill=False)
    eng = SpeculativeEngine(cfg, params, batch_size=2, max_len=32)
    with pytest.raises(ValueError, match="greedy-only"):
        eng.submit(GenRequest(prompt=np.arange(4, dtype=np.int32),
                              max_new=4, temperature=0.7))


def _guard_cfg_params():
    cfg = _base_cfg(emt_mode="ideal")
    return cfg, init_params(lm.specs(cfg), jax.random.PRNGKey(2))
