"""End-to-end system behaviour: the paper's pipeline on the framework's stack.

Train an EMT-aware model with techniques A+B, deploy with and without C, and
verify the headline claims: noise-aware training recovers accuracy lost by the
traditional optimizer, and C cuts deployment energy (Eqs. 18/20, Fig. 9).
"""
import dataclasses

import pytest

from benchmarks.ablation_lib import (run_method, method_config, train_cnn,
                                     evaluate, _with_rho, _emt)
from repro.configs.paper_cnn import vgg_small


@pytest.mark.slow
def test_noise_aware_training_recovers_accuracy():
    """traditional-on-EMT <= A-on-EMT (device-enhanced dataset helps) and the
    deployment energy of A+B+C is below A+B at similar accuracy."""
    base = vgg_small()
    r_trad = run_method(base, "traditional", rho=1.0, eval_rho=1.0, steps=90)
    r_a = run_method(base, "A", rho=1.0, steps=90)
    # at strong fluctuation (rho=1) noise-aware training should not be worse
    assert r_a["acc"] >= r_trad["acc"] - 0.03, (r_a, r_trad)

    r_ab = run_method(base, "A+B", rho=4.0, lam=3e-8, steps=90)
    r_abc = run_method(base, "A+B+C", rho=4.0, lam=3e-8, steps=90)
    assert r_abc["energy_uj"] < r_ab["energy_uj"], (r_abc, r_ab)
    assert r_abc["acc"] >= r_ab["acc"] - 0.1


def test_ideal_eval_beats_noisy_eval_for_traditional():
    """Sanity: the traditional model degrades when deployed on noisy EMT."""
    base = vgg_small()
    cfg_ideal = method_config(base, "traditional", rho=4.0)
    params = train_cnn(cfg_ideal, steps=80)
    acc_ideal, _ = evaluate(cfg_ideal, params)

    dep = dataclasses.replace(cfg_ideal,
                              emt=_emt("analog", 0.25, trainable=False))
    acc_noisy, _ = evaluate(dep, _with_rho(dep, params))
    assert acc_noisy <= acc_ideal + 0.02
