"""Paper CNN model + serving engine integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.paper_cnn import vgg_small, resnet_small
from repro.data.synthetic import SyntheticImages
from repro.models import cnn, lm
from repro.models.context import Ctx
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest


def test_cnn_forward_shapes_and_energy():
    for cfg in (vgg_small(), resnet_small()):
        params = init_params(cnn.specs(cfg), jax.random.PRNGKey(0))
        d = SyntheticImages(num_classes=cfg.num_classes,
                            image_size=cfg.image_size)
        b = d.batch(8, 0)
        logits, aux = cnn.forward(params, jnp.asarray(b["images"]), cfg,
                                  Ctx(seed=jnp.uint32(0)))
        assert logits.shape == (8, cfg.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert float(aux["energy_pj"]) > 0
        assert aux["cells"] > 0


def test_cnn_learns_quickly():
    from benchmarks.ablation_lib import train_cnn, evaluate
    cfg = vgg_small()
    # 180 steps plateaus at ~0.27 on this synthetic task; 300 reaches 1.0
    # deterministically (seed-fixed data + hash-RNG noise), with margin.
    params = train_cnn(cfg, steps=300, batch=32, seed=0)
    acc, energy = evaluate(cfg, params, batches=4)
    assert acc > 0.45, acc         # 4 classes, random = 0.25
    assert energy > 0


def test_serving_engine_greedy_deterministic():
    cfg = get_config("gemma3-1b", emt_mode="analog", smoke=True)
    cfg = cfg.replace(dtype=jnp.float32, num_layers=2)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, max_len=16, seed=3)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(prompt=rng.integers(0, cfg.vocab_size, 6)
                       .astype(np.int32), max_new=4) for _ in range(2)]
    outs1, e1 = eng.generate(reqs)
    outs2, e2 = eng.generate(reqs)
    for a, b in zip(outs1, outs2):
        np.testing.assert_array_equal(a, b)     # same seeds -> same fluctuation
    assert all(len(o) == 4 for o in outs1)
