"""Fake-quant STE: error bounds, gradients, integer levels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.quant import fake_quant, quant_levels, symmetric_scale


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_quant_error_bounded(bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    xq, scale = fake_quant(x, bits)
    assert float(jnp.max(jnp.abs(xq - x))) <= float(scale) / 2 + 1e-6


def test_ste_gradient_identity():
    x = jnp.linspace(-1, 1, 101)

    def f(x):
        xq, _ = fake_quant(x, 8)
        return jnp.sum(xq)

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g), 1.0, rtol=1e-6)


def test_levels_are_integers_in_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
    q, scale = quant_levels(x, 8)
    q = np.asarray(q)
    assert np.allclose(q, np.round(q), atol=1e-5)
    assert np.abs(q).max() <= 127
    # dequantized matches fake_quant
    xq, _ = fake_quant(x, 8)
    np.testing.assert_allclose(np.asarray(q) * float(scale), np.asarray(xq),
                               rtol=1e-5, atol=1e-6)


def test_per_channel_scales():
    x = jnp.stack([jnp.ones(16) * 0.1, jnp.ones(16) * 10.0])
    s = symmetric_scale(x, 8, axis=(1,))
    assert s.shape == (2, 1)
    assert float(s[1, 0]) / float(s[0, 0]) > 50
