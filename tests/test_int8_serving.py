"""Beyond-paper int8 weight-streaming serving mode."""
import jax
import jax.numpy as jnp

from repro.core import EMTConfig, emt_dense, dense_specs
from repro.core.emt_linear import quantize_tree_for_serving
from repro.nn.param import init_params, abstract_params
from repro.utils import tree_size_bytes


def test_int8_specs_halve_weight_bytes():
    f = EMTConfig(mode="analog")
    q = EMTConfig(mode="analog", store_int8=True)
    sf = abstract_params(dense_specs(256, 512, f, dtype=jnp.bfloat16))
    sq = abstract_params(dense_specs(256, 512, q, dtype=jnp.bfloat16))
    assert tree_size_bytes(sq) < tree_size_bytes(sf) * 0.55


def test_int8_matches_float_path():
    cfg_f = EMTConfig(mode="analog", rho_init=1e6)      # negligible noise
    cfg_q = EMTConfig(mode="analog", rho_init=1e6, store_int8=True)
    params = init_params(dense_specs(64, 32, cfg_f), jax.random.PRNGKey(0))
    params_q = quantize_tree_for_serving(params)
    assert "w_int8" in params_q and params_q["w_int8"].dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y_f, _ = emt_dense(params, x, cfg_f, tag="t", seed=0)
    y_q, _ = emt_dense(params_q, x, cfg_q, tag="t", seed=0)
    rel = float(jnp.linalg.norm(y_f - y_q) / jnp.linalg.norm(y_f))
    assert rel < 0.02, rel          # int8 quantization error only


def test_int8_with_noise_finite():
    cfg_q = EMTConfig(mode="analog", rho_init=2.0, store_int8=True)
    params = init_params(dense_specs(64, 32, cfg_q), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y, aux = emt_dense(params, x, cfg_q, tag="t", seed=3)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert aux["cells"] == 64 * 32


def test_quantize_tree_nested():
    cfg = EMTConfig(mode="analog")
    tree = {"a": dense_specs(16, 16, cfg), "b": {"c": dense_specs(16, 8, cfg)}}
    params = init_params(tree, jax.random.PRNGKey(0))
    q = quantize_tree_for_serving(params)
    assert "w_int8" in q["a"] and "w_int8" in q["b"]["c"]
    assert "rho_raw" in q["a"]
