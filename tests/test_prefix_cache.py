"""Refcounted prefix caching: token identity, refcount conservation, energy.

Property harness for PR 5's prefix cache (serve/kv_pool.py + chunked prefill):

* **token identity** — shared-prefix admission with caching on is
  token-identical at temperature 0 to caching off, in ideal mode and in
  analog mode with the per-row DAC scale (``a_per_row``), frozen noise —
  the settings under which stored K/V is exactly what a recompute would
  produce.
* **refcount conservation** — randomized submit/drain churn: no block is
  freed (or its content evicted) while referenced, every block is blank xor
  cached xor active exactly once, and nothing leaks after drain
  (``BlockPool.check``).
* **energy** — a fully cache-hit prefix bills zero incremental prefill
  tokens/energy/kv_reads: the skipped chunk steps never run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.nn.param import init_params
from repro.serve.engine import ServingEngine, GenRequest
from repro.serve.kv_pool import BlockPool, prefix_keys

BLOCK = 4


def _cfg(emt="ideal"):
    # prefix caching requires an all-global attention stack (no sliding
    # window ring); analog uses the per-row DAC scale so co-tenant occupancy
    # cannot perturb tokens (ROADMAP "Known subtlety")
    cfg = get_config("gemma3-1b", emt_mode=emt, smoke=True)
    cfg = cfg.replace(
        dtype=jnp.float32,
        num_layers=2,
        layer_pattern=("attn",),
        sliding_window=0,
        paged_attn_impl="ref",
    )
    if emt == "analog":
        cfg = cfg.replace(
            emt=cfg.emt.replace(
                quant=dataclasses.replace(cfg.emt.quant, a_per_row=True)
            )
        )
    return cfg


def _engine(cfg, params, prefix_cache, **kw):
    kw.setdefault("num_blocks", 24)
    return ServingEngine(
        cfg,
        params,
        batch_size=2,
        max_len=32,
        seed=7,
        fresh_noise=False,
        paged=True,
        block_size=BLOCK,
        prefill_chunk=8,
        prefix_cache=prefix_cache,
        **kw,
    )


def _shared_prefix_requests(cfg, rng, n=4, header=10, tail=6, max_new=4):
    head = rng.integers(0, cfg.vocab_size, header).astype(np.int32)
    return [
        GenRequest(
            prompt=np.concatenate(
                [head, rng.integers(0, cfg.vocab_size, tail).astype(np.int32)]
            ),
            max_new=max_new,
            seed=i,
        )
        for i in range(n)
    ]


@pytest.mark.parametrize("emt", ["ideal", "analog"])
def test_token_identity_caching_on_vs_off(emt):
    cfg = _cfg(emt)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = _shared_prefix_requests(cfg, rng)

    def run(pc):
        eng = _engine(cfg, params, pc)
        res = eng.serve(
            [
                GenRequest(prompt=r.prompt, max_new=r.max_new, seed=r.seed)
                for r in reqs
            ],
            stagger=3,
        )
        return eng, {r.rid: r.tokens for r in res}

    eng_off, off = run(False)
    eng_on, on = run(True)
    for rid in off:
        np.testing.assert_array_equal(
            on[rid],
            off[rid],
            err_msg=f"prefix cache changed tokens for request {rid} ({emt})",
        )
    # the cache actually engaged: later requests skipped the shared header
    assert eng_on.cached_prefix_tokens >= 2 * BLOCK
    assert eng_on.prefill_tokens_total < eng_off.prefill_tokens_total
    eng_on.kv.check()


def test_identical_prompt_and_partial_tail_cow():
    """An identical repeat prompt reuses every full block; a prompt diverging
    inside a registered block reuses its shared head copy-on-write."""
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    base = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)

    eng = _engine(cfg, params, True)
    eng.serve([GenRequest(prompt=base, max_new=3, seed=0)])
    assert eng.cached_prefix_tokens == 0

    # identical prompt: both full blocks shared, only the partial tail runs
    eng.serve([GenRequest(prompt=base, max_new=3, seed=0)])
    assert eng.cached_prefix_tokens == 2 * BLOCK

    # diverges at position 6, inside block 1: block 0 is a full hit and
    # block 1's first two rows are reused copy-on-write
    fork = base.copy()
    fork[6:] = (fork[6:] + 1) % cfg.vocab_size
    eng.serve([GenRequest(prompt=fork, max_new=3, seed=1)])
    assert eng.cached_prefix_tokens == 2 * BLOCK + BLOCK + 2
    eng.kv.check()

    # the forked stream matches a cache-off engine bit-exactly
    ref = _engine(cfg, params, False)
    want = ref.serve([GenRequest(prompt=fork, max_new=3, seed=1)])
    got = eng.serve([GenRequest(prompt=fork, max_new=3, seed=1)])
    np.testing.assert_array_equal(got[0].tokens, want[0].tokens)


def test_cache_hit_prefix_bills_zero_incremental_cost():
    """A resident prefix costs nothing to admit again: zero additional
    prefill tokens for the shared blocks, and strictly less energy and
    kv_reads than the cold admission of the same prompt."""
    cfg = _cfg("analog")
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = _engine(cfg, params, True)

    eng.serve([GenRequest(prompt=prompt, max_new=2, seed=0)])
    cold_tokens = eng.prefill_tokens_total
    cold_uj = eng.total_energy_pj
    cold_reads = eng.kv_reads_total
    assert cold_tokens == len(prompt)

    (res,) = eng.serve([GenRequest(prompt=prompt, max_new=2, seed=0)])
    warm_tokens = eng.prefill_tokens_total - cold_tokens
    warm_uj = eng.total_energy_pj - cold_uj
    warm_reads = eng.kv_reads_total - cold_reads
    # all 3 full blocks are resident: 2 as direct hits (the hit walk stops at
    # len - 1 so the final token's logits are recomputed) and the third's
    # leading 3 rows via copy-on-write -> only the last prompt token runs
    assert eng.cached_prefix_tokens == len(prompt) - 1
    assert warm_tokens == 1
    assert 0 < warm_uj < cold_uj
    assert 0 < warm_reads < cold_reads
    assert res.prefill_energy_pj > 0


def test_decode_written_blocks_register_and_serve_continuation():
    """Decode-block registration: blocks filled *during decode* enter the
    prefix registry under the written stream's rolling hashes, so replaying
    the conversation (prompt ++ greedy continuation) admits against them —
    the shared blocks bill zero incremental prefill tokens/energy."""
    cfg = _cfg("analog")
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    eng = _engine(cfg, params, True)

    # prompt (6) + 6 written decode tokens = 12 = 3 full blocks; the last
    # two blocks are filled by decode writes, not prefill
    (first,) = eng.serve([GenRequest(prompt=prompt, max_new=7, seed=0)])
    assert eng.cached_prefix_tokens == 0
    base_cached = eng.cached_prefix_tokens
    base_tokens = eng.prefill_tokens_total
    base_uj = eng.total_energy_pj

    # the few-shot continuation: the same conversation replayed as a prompt
    cont = np.concatenate([prompt, np.asarray(first.tokens, np.int32)])
    assert len(cont) == 13
    (second,) = eng.serve([GenRequest(prompt=cont, max_new=3, seed=1)])
    # all 3 full blocks hit — including the 2 decode-written ones — leaving
    # only the final prompt token to prefill
    assert eng.cached_prefix_tokens - base_cached == 3 * BLOCK
    assert eng.prefill_tokens_total - base_tokens == 1
    warm_uj = eng.total_energy_pj - base_uj
    assert 0 < warm_uj < base_uj
    eng.kv.check()

    # token identity: the continuation matches a cache-off engine bit-exactly
    ref = _engine(cfg, params, False)
    want = ref.serve([GenRequest(prompt=cont, max_new=3, seed=1)])
    np.testing.assert_array_equal(second.tokens, want[0].tokens)

    # a *repeated* continuation is free again (registration survives churn)
    mid_tokens = eng.prefill_tokens_total
    eng.serve([GenRequest(prompt=cont, max_new=3, seed=1)])
    assert eng.prefill_tokens_total - mid_tokens == 1


def test_refcount_conservation_under_churn():
    """Randomized serve churn over a tight pool: conservation after every
    drain, shared blocks never freed while referenced, no leak at the end."""
    cfg = _cfg()
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    head = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = _engine(cfg, params, True, num_blocks=12)
    for _ in range(6):
        n = int(rng.integers(1, 4))
        reqs = []
        for i in range(n):
            tail = rng.integers(0, cfg.vocab_size, int(rng.integers(1, 7)))
            reqs.append(
                GenRequest(
                    prompt=np.concatenate([head, tail.astype(np.int32)]),
                    max_new=int(rng.integers(1, 4)),
                    seed=i,
                )
            )
        eng.serve(reqs, stagger=int(rng.integers(0, 3)))
        eng.kv.check()
        pool = eng.kv.pool_g
        # drained: nothing may still hold a reference
        assert pool.num_owned == 0
        assert pool.num_free == pool.num_blocks
    assert eng.kv.pool_g.hits > 0


def test_blockpool_refcounts_and_eviction_unit():
    """Host-side allocator unit test: sharing, LRU eviction, conservation."""
    pool = BlockPool(4, BLOCK)
    toks = np.arange(BLOCK, dtype=np.int32)
    (key,) = prefix_keys(toks, BLOCK)

    ids = pool.alloc(0, 2)
    assert ids is not None and pool.refcount(ids[0]) == 1
    pool.register(ids[0], key, None, toks)
    pool.acquire(1, ids[0])
    assert pool.refcount(ids[0]) == 2
    pool.check()

    # owner 0 frees: the shared block survives with refcount 1, the private
    # one goes blank; no eviction happened
    blanks = pool.free(0)
    assert blanks == [ids[1]]
    assert pool.refcount(ids[0]) == 1
    pool.check()

    # owner 1 frees: the registered block parks in the cached-free list
    assert pool.free(1) == []
    assert pool.num_cached == 1
    assert pool.lookup(key) == ids[0]
    pool.check()

    # a full-pool alloc must evict the cached block (and report it for
    # zeroing), dropping the registration
    ids2 = pool.alloc(7, 4)
    assert ids2 is not None
    assert pool.lookup(key) is None
    assert pool.pop_evicted() == [ids[0]]
    pool.check()
    pool.free(7)
    assert pool.num_free == pool.num_blocks


def test_prefix_cache_requires_supported_stack():
    cfg = _cfg().replace(layer_pattern=("local", "global"), sliding_window=4)
    params = init_params(lm.specs(cfg), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="all-global"):
        ServingEngine(
            cfg,
            params,
            batch_size=2,
            max_len=16,
            paged=True,
            block_size=4,
            prefix_cache=True,
        )
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(
            cfg, params, batch_size=2, max_len=16, prefix_cache=True
        )
