"""Property tests (hypothesis) for technique C — the paper's Eqs. 16-20.

sigma(O_new) <= sigma(O_ori)  and  E_new <= E_ori  for every input level, with
equality only for 0/1-bit levels; plus Monte-Carlo confirmation on real matmuls.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import decompose
from repro.core.device import DeviceModel


@settings(deadline=None, max_examples=60)
@given(st.integers(0, 255))
def test_sigma_ratio_leq_one(level):
    """Eq. 18: sqrt(sum 4^p d_p) <= sum 2^p d_p for every level (bits of level)."""
    r = float(decompose.sigma_ratio_theory(jnp.float32(level), 8))
    assert r <= 1.0 + 1e-6
    popcount = bin(level).count("1")
    if popcount >= 2:
        assert r < 1.0 - 1e-6          # strict when >1 bit set (paper Eq. 17)
    else:
        assert abs(r - 1.0) < 1e-6


@settings(deadline=None, max_examples=60)
@given(st.integers(0, 255))
def test_energy_reads_leq_level(level):
    """Eq. 19-20: E_new = rho*sum(d_p) <= E_ori = rho*x."""
    pops = float(decompose.popcount_levels(jnp.float32(level), 8))
    assert pops <= level + 1e-6
    assert pops == bin(level).count("1")


def test_bitserial_exact_when_no_noise():
    """sigma -> 0 (rho -> inf): decomposition reproduces the exact product."""
    dev = DeviceModel()
    k = jax.random.PRNGKey(0)
    xq = jnp.round(jax.random.uniform(k, (16, 32), minval=-127, maxval=127))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y = decompose.bitserial_matmul_ref(xq, w, 1e9, dev, 7, seed=0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xq @ w),
                               rtol=2e-4, atol=2e-4)


def test_bitserial_lower_std_monte_carlo():
    """Empirical sigma(O_new) < sigma(O_ori) over independent fluctuation draws."""
    dev = DeviceModel()
    k = jax.random.PRNGKey(0)
    # levels with many bits set -> strong decomposition advantage
    xq = jnp.full((4, 64), 127.0)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    rho = 1.0

    outs_new, outs_ori = [], []
    from repro.core import hashrng
    sig = dev.sigma_rel(rho)
    for s in range(48):
        outs_new.append(decompose.bitserial_matmul_ref(
            xq, w, rho, dev, 7, seed=s, base_plane=0))
        offs = hashrng.tile_state_offsets(s, 0, 0, w.shape, dev.state_offsets,
                                          dev.state_probs, plane=12345)
        wn = w * (1 + offs * sig)
        outs_ori.append(xq @ wn)
    std_new = float(jnp.std(jnp.stack(outs_new), axis=0).mean())
    std_ori = float(jnp.std(jnp.stack(outs_ori), axis=0).mean())
    # levels=127 (7 bits): theory ratio = sqrt(sum 4^p)/sum 2^p ~= 0.743
    assert std_new < std_ori * 0.85
    theory = float(decompose.sigma_ratio_theory(jnp.float32(127), 7))
    assert abs(std_new / std_ori - theory) < 0.12


def test_gradient_is_ideal_matmul_vjp():
    dev = DeviceModel()
    xq = jnp.round(jax.random.uniform(jax.random.PRNGKey(2), (8, 16),
                                      minval=-31, maxval=31))
    w = jax.random.normal(jax.random.PRNGKey(3), (16, 4))

    def f(w):
        return jnp.sum(decompose.bitserial_matmul_ref(xq, w, 4.0, dev, 5))

    g = jax.grad(f)(w)
    expected = xq.T @ jnp.ones((8, 4))
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), rtol=1e-5)
