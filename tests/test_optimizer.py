"""Optimizers: convergence on a quadratic, factored shapes, clipping, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (Optimizer, OptimizerConfig,
                                   clip_by_global_norm, cosine_schedule)


@pytest.mark.parametrize("name,lr", [("sgd", 1.0), ("adamw", 0.1),
                                     ("adafactor", 0.05)])
def test_minimizes_quadratic(name, lr):
    opt = Optimizer(OptimizerConfig(name=name))
    target = jnp.linspace(-1, 1, 256).reshape(16, 16)
    params = {"w": jnp.zeros((16, 16))}
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    for step in range(500):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, lr,
                                   jnp.int32(step))
    assert float(loss(params)) < 1e-2, name


def test_adafactor_factored_state_shapes():
    opt = Optimizer(OptimizerConfig(name="adafactor", min_dim_factored=8))
    params = {"big": jnp.zeros((128, 64)), "small": jnp.zeros((4,)),
              "stack": jnp.zeros((3, 32, 16))}
    st = opt.init(params)
    assert st["big"]["vr"].shape == (128,)
    assert st["big"]["vc"].shape == (64,)
    assert st["small"]["v"].shape == (4,)
    assert st["stack"]["vr"].shape == (3, 32)
    assert st["stack"]["vc"].shape == (3, 16)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), np.sqrt(10 * 9 + 10 * 16))
    cn = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped))))
    assert np.isclose(cn, 1.0, rtol=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    # warmup starts at base/warmup (step 0 must not be a zero-update step)
    assert np.isclose(float(lr(jnp.int32(0))), 0.1)
    assert np.isclose(float(lr(jnp.int32(9))), 1.0)
    assert float(lr(jnp.int32(110))) <= 0.11
    assert float(lr(jnp.int32(60))) < float(lr(jnp.int32(20)))


def test_bf16_params_fp32_updates():
    opt = Optimizer(OptimizerConfig(name="adamw"))
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = opt.init(params)
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    new_p, _ = opt.update(g, state, params, 0.01, jnp.int32(0))
    assert new_p["w"].dtype == jnp.bfloat16
    assert float(new_p["w"][0, 0]) < 1.0
